//! Assignment step: nearest-centroid labels + per-cluster reduction.
//!
//! Two code paths with identical semantics (cross-checked in tests):
//!
//! * [`assign_accumulate`] — single-threaded blocked panel evaluation using
//!   the same `‖x‖² − 2x·c + ‖c‖²` decomposition as the Pallas kernel;
//! * [`assign_accumulate_parallel`] — row-blocked across a [`ThreadPool`],
//!   each worker reducing a private `(k, n)` partial that is merged at the
//!   end (the paper's parallelisation strategy 1).

use crate::metrics::Counters;
use crate::util::mem;
use crate::util::threadpool::ThreadPool;

use super::distance::{sq_dist_panel_argmin, sq_norm};

/// Rows per panel block — sized so a `(BLOCK, k)` distance panel stays in L2.
pub const BLOCK_ROWS: usize = 256;

/// Software-prefetch distance (in point rows) for linear row walks: far
/// enough ahead to hide DRAM latency behind one row's arithmetic, near
/// enough that the line is still resident when the walk reaches it.
pub const PREFETCH_ROWS_AHEAD: usize = 8;

/// Output of the fused assignment step.
#[derive(Clone, Debug)]
pub struct AssignOut {
    /// Nearest-centroid index per point.
    pub labels: Vec<u32>,
    /// Squared distance to the chosen centroid per point.
    pub mins: Vec<f32>,
    /// Per-cluster coordinate sums, row-major `(k, n)`.
    pub sums: Vec<f64>,
    /// Per-cluster sizes.
    pub counts: Vec<u64>,
    /// Chunk SSE = Σ mins (f64 accumulation).
    pub objective: f64,
}

/// Fused assignment + reduction over `points` (`m×n`) against `centroids`
/// (`k×n`). Counts `m·k` distance evaluations.
pub fn assign_accumulate(
    points: &[f32],
    centroids: &[f32],
    m: usize,
    n: usize,
    k: usize,
    counters: &mut Counters,
) -> AssignOut {
    assert_eq!(points.len(), m * n, "points shape");
    assert_eq!(centroids.len(), k * n, "centroids shape");
    assert!(k > 0, "k must be positive");
    let mut labels = vec![0u32; m];
    let mut mins = vec![0f32; m];
    let mut sums = vec![0f64; k * n];
    let mut counts = vec![0u64; k];
    let mut objective = 0f64;

    let c_sq: Vec<f32> = (0..k).map(|j| sq_norm(&centroids[j * n..(j + 1) * n])).collect();
    let mut x_sq = vec![0f32; BLOCK_ROWS];

    let mut row = 0;
    while row < m {
        let rows = BLOCK_ROWS.min(m - row);
        let block = &points[row * n..(row + rows) * n];
        for (i, xs) in x_sq.iter_mut().take(rows).enumerate() {
            *xs = sq_norm(&block[i * n..(i + 1) * n]);
        }
        // Fused panel + argmin: the per-row best is reduced inside the panel
        // loop, so no `rows×k` distance buffer is materialised.
        sq_dist_panel_argmin(
            block,
            &x_sq[..rows],
            centroids,
            &c_sq,
            rows,
            k,
            n,
            &mut labels[row..row + rows],
            &mut mins[row..row + rows],
        );
        for i in 0..rows {
            let g = row + i;
            let best = labels[g] as usize;
            let best_d = mins[g];
            objective += best_d as f64;
            counts[best] += 1;
            let srow = &mut sums[best * n..(best + 1) * n];
            let x = &block[i * n..(i + 1) * n];
            for (sv, xv) in srow.iter_mut().zip(x) {
                *sv += *xv as f64;
            }
        }
        row += rows;
    }
    counters.add_distance_evals((m * k) as u64);
    AssignOut { labels, mins, sums, counts, objective }
}

/// Labels + min-distances only (no reduction) — the final full-dataset
/// assignment pass and the D² weights for K-means++ use this.
///
/// Runs the same fused `‖x‖² − 2x·c + ‖c‖²` panel + in-register argmin as
/// [`assign_accumulate`], so every stateless pass in the crate shares one
/// canonical per-point arithmetic: a single-centroid decomposition
/// evaluation ([`super::distance::sq_dist_decomp`]) of the winning pair is
/// bit-identical to the value reported here — the exactness contract the
/// block-pruned final pass rests on.
pub fn assign_only(
    points: &[f32],
    centroids: &[f32],
    m: usize,
    n: usize,
    k: usize,
    counters: &mut Counters,
) -> (Vec<u32>, Vec<f32>) {
    assert_eq!(points.len(), m * n);
    assert_eq!(centroids.len(), k * n);
    let mut labels = vec![0u32; m];
    let mut mins = vec![0f32; m];
    let c_sq: Vec<f32> = (0..k).map(|j| sq_norm(&centroids[j * n..(j + 1) * n])).collect();
    panel_assign_into(points, centroids, &c_sq, m, n, k, &mut labels, &mut mins);
    counters.add_distance_evals((m * k) as u64);
    (labels, mins)
}

/// Pool-sharded stateless assignment into caller-owned buffers — the
/// serve-mode batched query entry point. Rows are carved across the pool
/// by [`partition_rows`] (falling back to one inline [`panel_assign_into`]
/// pass when the batch is too small to parallelise); since per-point
/// results are tiling-independent, the filled `labels`/`mins` are
/// **bit-identical to [`assign_only`]** for every pool size. `c_sq` must
/// be the per-centroid squared norms in centroid order (what
/// [`assign_only`] computes internally) — precomputing it once per model
/// is what lets a daemon amortise it across requests.
#[allow(clippy::too_many_arguments)]
pub fn assign_only_pooled(
    pool: &ThreadPool,
    points: &[f32],
    centroids: &[f32],
    c_sq: &[f32],
    m: usize,
    n: usize,
    k: usize,
    labels: &mut [u32],
    mins: &mut [f32],
    counters: &mut Counters,
) {
    assert_eq!(points.len(), m * n, "points shape");
    assert_eq!(centroids.len(), k * n, "centroids shape");
    assert_eq!(c_sq.len(), k, "c_sq shape");
    assert_eq!(labels.len(), m, "labels shape");
    assert_eq!(mins.len(), m, "mins shape");
    match partition_rows(pool, m) {
        None => panel_assign_into(points, centroids, c_sq, m, n, k, labels, mins),
        Some(parts) => {
            // partition_rows yields contiguous shards from row 0, so the
            // output slices can be peeled off front to back.
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(parts.len());
            let mut l_rest = labels;
            let mut d_rest = mins;
            for (start, end) in parts {
                let take = end - start;
                let (l, lr) = l_rest.split_at_mut(take);
                let (d, dr) = d_rest.split_at_mut(take);
                l_rest = lr;
                d_rest = dr;
                let pts = &points[start * n..end * n];
                jobs.push(Box::new(move || {
                    panel_assign_into(pts, centroids, c_sq, take, n, k, l, d);
                }));
            }
            pool.scope_run_all(jobs);
        }
    }
    counters.add_distance_evals((m as u64) * (k as u64));
}

/// The shared stateless panel pass: fills `labels`/`mins` for `rows`
/// points using [`sq_dist_panel_argmin`] over `BLOCK_ROWS`-row tiles with
/// precomputed centroid norms. Per-point results are independent of the
/// tiling, so callers may carve `rows` arbitrarily (worker shards, pruned
/// final-pass segments) and still get bit-identical values.
#[allow(clippy::too_many_arguments)]
pub fn panel_assign_into(
    points: &[f32],
    centroids: &[f32],
    c_sq: &[f32],
    rows: usize,
    n: usize,
    k: usize,
    labels: &mut [u32],
    mins: &mut [f32],
) {
    debug_assert_eq!(points.len(), rows * n);
    debug_assert_eq!(centroids.len(), k * n);
    debug_assert_eq!(labels.len(), rows);
    debug_assert_eq!(mins.len(), rows);
    let mut x_sq = vec![0f32; BLOCK_ROWS.min(rows.max(1))];
    let limit = points.len();
    let mut row = 0;
    while row < rows {
        let take = BLOCK_ROWS.min(rows - row);
        let block = &points[row * n..(row + take) * n];
        for (i, xs) in x_sq.iter_mut().take(take).enumerate() {
            // The norm pass is the first touch of each tile; prefetching a
            // few rows ahead hides DRAM latency on out-of-cache shards
            // (serve batches, final-pass slabs). The panel pass right
            // after re-reads the tile from cache. Clamping to one-past-end
            // keeps the pointer arithmetic defined; the hint never faults.
            let ahead = (row + i + PREFETCH_ROWS_AHEAD) * n;
            mem::prefetch_read(points.as_ptr().wrapping_add(ahead.min(limit)) as *const u8);
            *xs = sq_norm(&block[i * n..(i + 1) * n]);
        }
        sq_dist_panel_argmin(
            block,
            &x_sq[..take],
            centroids,
            c_sq,
            take,
            k,
            n,
            &mut labels[row..row + take],
            &mut mins[row..row + take],
        );
        row += take;
    }
}

/// Parallel fused assignment: row blocks on the pool, partials merged.
/// Semantically identical to [`assign_accumulate`].
///
/// Workers borrow `points` and `centroids` directly through the pool's
/// scoped API — no `O(m·n)` buffer cloning per call (the assignment step
/// runs every Lloyd iteration, so a copy here used to dominate allocation
/// on the hot path).
/// Contiguous per-worker row blocks shared by every pool-parallel
/// assignment path (panel and bounded engines alike); `None` when the
/// problem is too small to parallelise. Keeping the rule in one place is
/// what guarantees engine-independent thresholds and merge order.
pub(crate) fn partition_rows(pool: &ThreadPool, m: usize) -> Option<Vec<(usize, usize)>> {
    let nworkers = pool.size().min(m.max(1));
    if nworkers <= 1 || m < 2 * BLOCK_ROWS {
        return None;
    }
    let block = m.div_ceil(nworkers);
    Some(
        (0..nworkers)
            .map(|w| (w * block, ((w + 1) * block).min(m)))
            .filter(|(s, e)| s < e)
            .collect(),
    )
}

pub fn assign_accumulate_parallel(
    pool: &ThreadPool,
    points: &[f32],
    centroids: &[f32],
    m: usize,
    n: usize,
    k: usize,
    counters: &mut Counters,
) -> AssignOut {
    assert_eq!(points.len(), m * n);
    assert_eq!(centroids.len(), k * n);
    let Some(jobs) = partition_rows(pool, m) else {
        return assign_accumulate(points, centroids, m, n, k, counters);
    };
    // One output slot per worker, written in place by the scoped jobs.
    let mut partials: Vec<Option<(usize, AssignOut)>> =
        (0..jobs.len()).map(|_| None).collect();
    let closures: Vec<_> = jobs
        .into_iter()
        .zip(partials.iter_mut())
        .map(|((start, end), slot)| {
            move || {
                let mut local = Counters::new();
                let rows = end - start;
                let out = assign_accumulate(
                    &points[start * n..end * n],
                    centroids,
                    rows,
                    n,
                    k,
                    &mut local,
                );
                *slot = Some((start, out));
            }
        })
        .collect();
    pool.scope_run_all(closures);
    let mut labels = vec![0u32; m];
    let mut mins = vec![0f32; m];
    let mut sums = vec![0f64; k * n];
    let mut counts = vec![0u64; k];
    let mut objective = 0f64;
    for part in partials.into_iter().flatten() {
        let (start, out) = part;
        let rows = out.labels.len();
        labels[start..start + rows].copy_from_slice(&out.labels);
        mins[start..start + rows].copy_from_slice(&out.mins);
        for (acc, v) in sums.iter_mut().zip(&out.sums) {
            *acc += *v;
        }
        for (acc, v) in counts.iter_mut().zip(&out.counts) {
            *acc += *v;
        }
        objective += out.objective;
    }
    counters.add_distance_evals((m * k) as u64);
    AssignOut { labels, mins, sums, counts, objective }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (Vec<f32>, Vec<f32>) {
        // Two tight blobs around (0,0) and (10,10).
        let mut pts = Vec::new();
        for i in 0..8 {
            let o = (i % 4) as f32 * 0.01;
            if i < 4 {
                pts.extend_from_slice(&[o, o]);
            } else {
                pts.extend_from_slice(&[10.0 + o, 10.0 + o]);
            }
        }
        let cs = vec![0.0, 0.0, 10.0, 10.0];
        (pts, cs)
    }

    #[test]
    fn fused_assignment_blobs() {
        let (pts, cs) = toy();
        let mut c = Counters::new();
        let out = assign_accumulate(&pts, &cs, 8, 2, 2, &mut c);
        assert_eq!(&out.labels[..4], &[0, 0, 0, 0]);
        assert_eq!(&out.labels[4..], &[1, 1, 1, 1]);
        assert_eq!(out.counts, vec![4, 4]);
        assert_eq!(c.distance_evals, 16);
        // Sums reconstruct means near the blob centers.
        let mean0 = out.sums[0] / 4.0;
        assert!((mean0 - 0.015).abs() < 1e-5);
    }

    #[test]
    fn fused_matches_assign_only() {
        let mut rng = crate::util::rng::Rng::new(1);
        let (m, n, k) = (517, 7, 5); // deliberately not block-aligned
        let pts: Vec<f32> = (0..m * n).map(|_| rng.f32() * 10.0).collect();
        let cs: Vec<f32> = (0..k * n).map(|_| rng.f32() * 10.0).collect();
        let mut c1 = Counters::new();
        let mut c2 = Counters::new();
        let fused = assign_accumulate(&pts, &cs, m, n, k, &mut c1);
        let (labels, mins) = assign_only(&pts, &cs, m, n, k, &mut c2);
        assert_eq!(fused.labels, labels);
        for (a, b) in fused.mins.iter().zip(&mins) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
        assert_eq!(c1.distance_evals, c2.distance_evals);
    }

    #[test]
    fn counts_total_m_and_objective_matches_mins() {
        let mut rng = crate::util::rng::Rng::new(2);
        let (m, n, k) = (300, 4, 3);
        let pts: Vec<f32> = (0..m * n).map(|_| rng.f32()).collect();
        let cs: Vec<f32> = (0..k * n).map(|_| rng.f32()).collect();
        let mut c = Counters::new();
        let out = assign_accumulate(&pts, &cs, m, n, k, &mut c);
        assert_eq!(out.counts.iter().sum::<u64>(), m as u64);
        let sum_mins: f64 = out.mins.iter().map(|&x| x as f64).sum();
        assert!((out.objective - sum_mins).abs() < 1e-3);
    }

    #[test]
    fn pooled_assign_bit_identical_to_assign_only() {
        let mut rng = crate::util::rng::Rng::new(7);
        // Odd row counts straddle the partition threshold and leave a
        // ragged tail shard; every pool size must agree bit-for-bit.
        for m in [17usize, 511, 513, 2048 + 13] {
            let (n, k) = (5, 7);
            let pts: Vec<f32> = (0..m * n).map(|_| rng.f32() * 9.0 - 4.5).collect();
            let cs: Vec<f32> = (0..k * n).map(|_| rng.f32() * 9.0 - 4.5).collect();
            let c_sq: Vec<f32> =
                (0..k).map(|j| sq_norm(&cs[j * n..(j + 1) * n])).collect();
            let mut c1 = Counters::new();
            let (want_labels, want_mins) = assign_only(&pts, &cs, m, n, k, &mut c1);
            for threads in [1usize, 2, 5] {
                let pool = ThreadPool::new(threads);
                let mut labels = vec![0u32; m];
                let mut mins = vec![0f32; m];
                let mut c2 = Counters::new();
                assign_only_pooled(
                    &pool, &pts, &cs, &c_sq, m, n, k, &mut labels, &mut mins, &mut c2,
                );
                assert_eq!(labels, want_labels, "m={m} threads={threads}");
                let same = mins
                    .iter()
                    .zip(&want_mins)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "mins must be bit-identical (m={m} threads={threads})");
                assert_eq!(c1.distance_evals, c2.distance_evals);
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = crate::util::rng::Rng::new(3);
        let (m, n, k) = (2048, 6, 4);
        let pts: Vec<f32> = (0..m * n).map(|_| rng.f32() * 5.0).collect();
        let cs: Vec<f32> = (0..k * n).map(|_| rng.f32() * 5.0).collect();
        let pool = ThreadPool::new(4);
        let mut c1 = Counters::new();
        let mut c2 = Counters::new();
        let serial = assign_accumulate(&pts, &cs, m, n, k, &mut c1);
        let par = assign_accumulate_parallel(&pool, &pts, &cs, m, n, k, &mut c2);
        assert_eq!(serial.labels, par.labels);
        assert_eq!(serial.counts, par.counts);
        assert!((serial.objective - par.objective).abs() < 1e-6 * serial.objective.abs());
        assert_eq!(c1.distance_evals, c2.distance_evals);
    }
}
