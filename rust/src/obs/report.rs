//! Structured, versioned run reports: every number a run acted on, in one
//! JSON document a reviewer (or CI) can audit after the fact.
//!
//! The paper's protocol reports objectives and distance-evaluation counts;
//! the tuner paper (arXiv 2403.18766) adds bandit pulls and rewards. A
//! [`RunReport`] collects all of it — per-shot objective descent, the
//! bandit decision audit, stream drift/remediation events, engine + ISA
//! mix, and the work counters — under a `schema` tag
//! ([`REPORT_SCHEMA`]) so downstream tooling can reject drift.
//!
//! Collection follows the `obs` observer contract: the process-wide
//! [`report_sink`] is a relaxed-atomic no-op until `cluster --report`
//! enables it, and recording happens *after* each shot's offer is decided,
//! so the sink can never perturb the search. The `report` subcommand
//! renders the JSON to a self-contained zero-dependency HTML page with
//! inline SVG descent and shot-latency charts.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::json::{self, Json};
use crate::util::sync::lock_recover;

/// Schema tag of the run-report document (bump on breaking change).
pub const REPORT_SCHEMA: &str = "bigmeans.run_report.v1";

/// One shot, as the executor saw it.
#[derive(Clone, Debug)]
pub struct ShotEvent {
    /// Sink arrival order (equals shot order at one worker).
    pub seq: u64,
    /// Chunk-local SSE of the converged centroids.
    pub chunk_objective: f64,
    /// Objective offered to the incumbent (validation objective under the
    /// tuner's scorer, else the chunk objective).
    pub offered_objective: f64,
    /// Whether the incumbent accepted the offer.
    pub accepted: bool,
    /// Lloyd iterations the local search took.
    pub iters: u32,
    /// Shot wall time, when the executor had a clock running (observers
    /// enabled); `None` otherwise.
    pub secs: Option<f64>,
}

impl ShotEvent {
    fn to_json(&self) -> Json {
        // NaN/∞ have no JSON text form — degrade to null (which the lint
        // then rejects as "not a number", by design) rather than emit a
        // document that cannot be parsed back.
        let fnum = |x: f64| if x.is_finite() { json::num(x) } else { Json::Null };
        json::obj(vec![
            ("seq", json::num(self.seq as f64)),
            ("chunk_objective", fnum(self.chunk_objective)),
            ("offered_objective", fnum(self.offered_objective)),
            ("accepted", Json::Bool(self.accepted)),
            ("iters", json::num(self.iters as f64)),
            ("secs", self.secs.map(json::num).unwrap_or(Json::Null)),
        ])
    }
}

/// Process-wide shot-event collector. Disabled by default; the executors
/// record into it only when enabled, after the offer is decided.
pub struct ReportSink {
    enabled: AtomicBool,
    shots: Mutex<Vec<ShotEvent>>,
}

impl ReportSink {
    fn new() -> ReportSink {
        ReportSink { enabled: AtomicBool::new(false), shots: Mutex::new(Vec::new()) }
    }

    pub fn enable(&self) {
        self.enabled.store(true, Ordering::Relaxed);
    }

    pub fn disable_and_clear(&self) {
        self.enabled.store(false, Ordering::Relaxed);
        lock_recover(&self.shots).clear();
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Record one shot. A relaxed-atomic no-op unless the sink is
    /// enabled — call sites tap unconditionally, so this guard is what
    /// keeps a default (no `--report`) run from buffering events without
    /// bound or taking the shots mutex on the hot path.
    pub fn record_shot(
        &self,
        chunk_objective: f64,
        offered_objective: f64,
        accepted: bool,
        iters: u32,
        secs: Option<f64>,
    ) {
        if !self.enabled() {
            return;
        }
        let mut shots = lock_recover(&self.shots);
        let seq = shots.len() as u64;
        shots.push(ShotEvent {
            seq,
            chunk_objective,
            offered_objective,
            accepted,
            iters,
            secs,
        });
    }

    /// Take every buffered event, oldest first, leaving the sink enabled.
    pub fn drain(&self) -> Vec<ShotEvent> {
        std::mem::take(&mut *lock_recover(&self.shots))
    }
}

/// The process-wide report sink singleton.
pub fn report_sink() -> &'static ReportSink {
    static SINK: OnceLock<ReportSink> = OnceLock::new();
    SINK.get_or_init(ReportSink::new)
}

/// Builder for the versioned report document. The CLI assembles one per
/// run from the sink's shot events plus whatever the mode produced (tuner
/// trace, stream validation trace, counters, result objective).
pub struct RunReport {
    /// `cluster` / `tune` / `stream`.
    pub mode: String,
    /// Run configuration echo: k, s, engine, isa, backend, threads, seed.
    pub config: Vec<(&'static str, Json)>,
    /// Per-shot descent events from the sink.
    pub shots: Vec<ShotEvent>,
    /// Final result summary (objective, improvements, timings).
    pub result: Vec<(&'static str, Json)>,
    /// Work counters (distance_evals, pruned_evals, pruned_blocks, ...).
    pub counters: Vec<(&'static str, Json)>,
    /// Bandit audit (`TunerTrace::to_json`), tune mode only.
    pub tuner: Option<Json>,
    /// Stream drift audit (validation trace, drift/remediation counts).
    pub stream: Option<Json>,
}

impl RunReport {
    pub fn new(mode: &str) -> RunReport {
        RunReport {
            mode: mode.to_string(),
            config: Vec::new(),
            shots: Vec::new(),
            result: Vec::new(),
            counters: Vec::new(),
            tuner: None,
            stream: None,
        }
    }

    pub fn to_json(&self) -> Json {
        let accepted = self.shots.iter().filter(|s| s.accepted).count();
        json::obj(vec![
            ("schema", json::s(REPORT_SCHEMA)),
            ("written_at", json::s(&super::log::timestamp_utc())),
            ("mode", json::s(&self.mode)),
            ("config", json::obj(self.config.clone())),
            ("shots", json::arr(self.shots.iter().map(|s| s.to_json()).collect())),
            ("shots_total", json::num(self.shots.len() as f64)),
            ("shots_accepted", json::num(accepted as f64)),
            ("result", json::obj(self.result.clone())),
            ("counters", json::obj(self.counters.clone())),
            ("tuner", self.tuner.clone().unwrap_or(Json::Null)),
            ("stream", self.stream.clone().unwrap_or(Json::Null)),
        ])
    }
}

/// Validate a run-report document: schema tag, required keys, shot-array
/// shape, and internal consistency of the accepted count. Returns the
/// number of shots on success (the lint CLI prints it).
pub fn lint_report(doc: &Json) -> Result<usize, String> {
    let schema = doc
        .get("schema")
        .and_then(|s| s.as_str())
        .ok_or("report: missing schema tag")?;
    if schema != REPORT_SCHEMA {
        return Err(format!("report: unknown schema '{schema}' (expected {REPORT_SCHEMA})"));
    }
    for key in ["written_at", "mode", "config", "shots", "result", "counters"] {
        if doc.get(key).is_none() {
            return Err(format!("report: missing key '{key}'"));
        }
    }
    let shots = doc
        .get("shots")
        .and_then(|s| s.as_arr())
        .ok_or("report: 'shots' must be an array")?;
    let mut accepted = 0usize;
    for (i, shot) in shots.iter().enumerate() {
        for key in ["seq", "chunk_objective", "offered_objective", "accepted", "iters"] {
            if shot.get(key).is_none() {
                return Err(format!("report: shot {i} missing '{key}'"));
            }
        }
        let offered = shot
            .get("offered_objective")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("report: shot {i} offered_objective not a number"))?;
        if !offered.is_finite() {
            return Err(format!("report: shot {i} offered_objective not finite"));
        }
        if shot.get("accepted") == Some(&Json::Bool(true)) {
            accepted += 1;
        }
    }
    if let Some(total) = doc.get("shots_total").and_then(|v| v.as_usize()) {
        if total != shots.len() {
            return Err(format!("report: shots_total {total} != shots array len {}", shots.len()));
        }
    }
    if let Some(acc) = doc.get("shots_accepted").and_then(|v| v.as_usize()) {
        if acc != accepted {
            return Err(format!("report: shots_accepted {acc} != counted {accepted}"));
        }
    }
    Ok(shots.len())
}

/// Render a report document as a self-contained HTML page: metadata
/// tables plus inline SVG charts (objective descent over shots, per-shot
/// latency). Zero external assets — the page works from `file://`.
pub fn render_html(doc: &Json) -> String {
    let mode = doc.get("mode").and_then(|v| v.as_str()).unwrap_or("?");
    let written = doc.get("written_at").and_then(|v| v.as_str()).unwrap_or("?");
    let shots: Vec<Json> =
        doc.get("shots").and_then(|v| v.as_arr()).map(|a| a.to_vec()).unwrap_or_default();

    let offered: Vec<f64> = shots
        .iter()
        .filter_map(|s| s.get("offered_objective").and_then(|v| v.as_f64()))
        .collect();
    let accepted_idx: Vec<usize> = shots
        .iter()
        .enumerate()
        .filter(|(_, s)| s.get("accepted") == Some(&Json::Bool(true)))
        .map(|(i, _)| i)
        .collect();
    // Incumbent descent: running minimum of accepted offers.
    let mut best = f64::INFINITY;
    let descent: Vec<f64> = shots
        .iter()
        .map(|s| {
            let acc = s.get("accepted") == Some(&Json::Bool(true));
            let off = s.get("offered_objective").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
            if acc && off < best {
                best = off;
            }
            best
        })
        .collect();
    let secs: Vec<f64> = shots
        .iter()
        .map(|s| s.get("secs").and_then(|v| v.as_f64()).unwrap_or(0.0))
        .collect();

    let mut html = String::with_capacity(16 * 1024);
    html.push_str("<!doctype html>\n<html lang=\"en\"><head><meta charset=\"utf-8\">\n");
    html.push_str(&format!("<title>bigmeans run report — {}</title>\n", escape(mode)));
    html.push_str(
        "<style>body{font:14px/1.5 system-ui,sans-serif;margin:2rem auto;max-width:60rem;\
         color:#222}table{border-collapse:collapse;margin:1rem 0}td,th{border:1px solid #ccc;\
         padding:.25rem .6rem;text-align:left}h1,h2{font-weight:600}svg{background:#fafafa;\
         border:1px solid #ddd}code{background:#f3f3f3;padding:0 .25rem}.muted{color:#888}\
         </style></head><body>\n",
    );
    html.push_str(&format!(
        "<h1>bigmeans run report</h1>\n<p class=\"muted\">mode <code>{}</code> · written {} · \
         schema <code>{}</code></p>\n",
        escape(mode),
        escape(written),
        escape(doc.get("schema").and_then(|v| v.as_str()).unwrap_or("?")),
    ));

    let sections =
        [("Configuration", "config"), ("Result", "result"), ("Counters", "counters")];
    for (title, key) in sections {
        if let Some(Json::Obj(map)) = doc.get(key) {
            if map.is_empty() {
                continue;
            }
            html.push_str(&format!("<h2>{title}</h2>\n<table>\n"));
            for (k, v) in map {
                html.push_str(&format!(
                    "<tr><th>{}</th><td>{}</td></tr>\n",
                    escape(k),
                    escape(&v.to_string())
                ));
            }
            html.push_str("</table>\n");
        }
    }

    if !offered.is_empty() {
        html.push_str("<h2>Objective descent</h2>\n");
        html.push_str(&format!(
            "<p class=\"muted\">{} shots, {} accepted; grey = offered objective, \
             blue = incumbent (running best of accepted offers).</p>\n",
            shots.len(),
            accepted_idx.len()
        ));
        html.push_str(&svg_lines(
            &[("#bbb", &offered[..]), ("#1a6fd4", &descent[..])],
            &accepted_idx,
            720,
            260,
        ));
    }
    if secs.iter().any(|&s| s > 0.0) {
        html.push_str("<h2>Shot latency</h2>\n");
        html.push_str(&svg_bars(&secs, 720, 160));
    }

    if let Some(tuner) = doc.get("tuner") {
        if let Some(arms) = tuner.get("arms").and_then(|a| a.as_arr()) {
            html.push_str(
                "<h2>Bandit audit</h2>\n<table>\n<tr><th>arm</th><th>kernel</th>\
                 <th>pulls</th><th>accepted</th><th>mean reward</th>\
                 <th>distance evals</th></tr>\n",
            );
            for arm in arms {
                html.push_str(&format!(
                    "<tr><td>{}</td><td>{}</td><td>{}</td><td>{}</td>\
                     <td>{:.4}</td><td>{}</td></tr>\n",
                    escape(arm.get("label").and_then(|v| v.as_str()).unwrap_or("?")),
                    escape(arm.get("kernel").and_then(|v| v.as_str()).unwrap_or("?")),
                    arm.get("pulls").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    arm.get("accepted").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    arm.get("mean_reward").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    arm.get("distance_evals").and_then(|v| v.as_f64()).unwrap_or(0.0),
                ));
            }
            html.push_str("</table>\n");
        }
    }
    if let Some(stream) = doc.get("stream") {
        if let Some(trace) = stream.get("validation_trace").and_then(|a| a.as_arr()) {
            html.push_str("<h2>Stream drift audit</h2>\n");
            html.push_str(&format!(
                "<p class=\"muted\">drift events: {} · remediations: {}</p>\n",
                stream.get("drift_events").and_then(|v| v.as_f64()).unwrap_or(0.0),
                stream.get("remediations").and_then(|v| v.as_f64()).unwrap_or(0.0),
            ));
            let vals: Vec<f64> =
                trace.iter().filter_map(|p| p.get("objective").and_then(|v| v.as_f64())).collect();
            if !vals.is_empty() {
                html.push_str(&svg_lines(&[("#b3541e", &vals[..])], &[], 720, 160));
            }
        }
    }
    html.push_str("</body></html>\n");
    html
}

fn escape(text: &str) -> String {
    text.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

/// Polyline chart over shot index; `marks` indices get circles on the
/// first series.
fn svg_lines(series: &[(&str, &[f64])], marks: &[usize], w: usize, h: usize) -> String {
    let finite: Vec<f64> = series
        .iter()
        .flat_map(|(_, vals)| vals.iter().copied())
        .filter(|v| v.is_finite())
        .collect();
    if finite.is_empty() {
        return String::new();
    }
    let (lo, hi) = bounds(&finite);
    let pad = 12.0;
    let n_max = series.iter().map(|(_, v)| v.len()).max().unwrap_or(1).max(2);
    let x = |i: usize| pad + (w as f64 - 2.0 * pad) * i as f64 / (n_max - 1) as f64;
    let y = |v: f64| {
        let t = if hi > lo { (v - lo) / (hi - lo) } else { 0.5 };
        (h as f64 - pad) - t * (h as f64 - 2.0 * pad)
    };
    let mut svg = format!("<svg viewBox=\"0 0 {w} {h}\" width=\"{w}\" height=\"{h}\">\n");
    for (color, vals) in series {
        let pts: Vec<String> = vals
            .iter()
            .enumerate()
            .filter(|(_, v)| v.is_finite())
            .map(|(i, &v)| format!("{:.1},{:.1}", x(i), y(v)))
            .collect();
        if pts.len() >= 2 {
            svg.push_str(&format!(
                "<polyline fill=\"none\" stroke=\"{color}\" stroke-width=\"1.5\" points=\"{}\"/>\n",
                pts.join(" ")
            ));
        }
    }
    if let Some((_, first)) = series.first() {
        for &i in marks {
            if let Some(&v) = first.get(i) {
                if v.is_finite() {
                    svg.push_str(&format!(
                        "<circle cx=\"{:.1}\" cy=\"{:.1}\" r=\"2.5\" fill=\"#1a6fd4\"/>\n",
                        x(i),
                        y(v)
                    ));
                }
            }
        }
    }
    svg.push_str(&format!(
        "<text x=\"{pad}\" y=\"11\" font-size=\"10\" fill=\"#888\">max {hi:.4e}</text>\n\
         <text x=\"{pad}\" y=\"{}\" font-size=\"10\" fill=\"#888\">min {lo:.4e}</text>\n</svg>\n",
        h as f64 - 2.0,
    ));
    svg
}

/// Bar chart of per-shot values (latency).
fn svg_bars(vals: &[f64], w: usize, h: usize) -> String {
    let finite: Vec<f64> = vals.iter().copied().filter(|v| v.is_finite() && *v >= 0.0).collect();
    if finite.is_empty() {
        return String::new();
    }
    let hi = finite.iter().cloned().fold(0.0f64, f64::max).max(1e-12);
    let pad = 12.0;
    let bw = ((w as f64 - 2.0 * pad) / vals.len() as f64).max(0.5);
    let mut svg = format!("<svg viewBox=\"0 0 {w} {h}\" width=\"{w}\" height=\"{h}\">\n");
    for (i, &v) in vals.iter().enumerate() {
        if !v.is_finite() || v <= 0.0 {
            continue;
        }
        let bh = (v / hi) * (h as f64 - 2.0 * pad);
        svg.push_str(&format!(
            "<rect x=\"{:.1}\" y=\"{:.1}\" width=\"{:.1}\" height=\"{:.1}\" fill=\"#5a9\"/>\n",
            pad + i as f64 * bw,
            (h as f64 - pad) - bh,
            (bw - 0.4).max(0.3),
            bh
        ));
    }
    svg.push_str(&format!(
        "<text x=\"{pad}\" y=\"11\" font-size=\"10\" fill=\"#888\">max {:.2} ms</text>\n</svg>\n",
        hi * 1e3
    ));
    svg
}

fn bounds(vals: &[f64]) -> (f64, f64) {
    let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> RunReport {
        let mut report = RunReport::new("cluster");
        report.config.push(("k", json::num(4.0)));
        report.config.push(("engine", json::s("hybrid")));
        report.result.push(("objective", json::num(123.5)));
        report.counters.push(("distance_evals", json::num(9999.0)));
        for i in 0..10u64 {
            report.shots.push(ShotEvent {
                seq: i,
                chunk_objective: 100.0 - i as f64,
                offered_objective: 100.0 - i as f64,
                accepted: i % 3 == 0,
                iters: 5,
                secs: Some(0.001 * (i + 1) as f64),
            });
        }
        report
    }

    #[test]
    fn report_roundtrips_and_lints() {
        let doc = sample_report().to_json();
        let text = doc.to_string();
        let back = Json::parse(&text).expect("report JSON parses");
        assert_eq!(lint_report(&back), Ok(10));
        assert_eq!(back.get("schema").unwrap().as_str(), Some(REPORT_SCHEMA));
        assert_eq!(back.get("shots_accepted").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn lint_rejects_bad_documents() {
        assert!(lint_report(&json::obj(vec![])).is_err());
        let wrong_schema = json::obj(vec![("schema", json::s("nope.v0"))]);
        assert!(lint_report(&wrong_schema).unwrap_err().contains("unknown schema"));
        // A NaN objective degrades to null in the document; the lint then
        // rejects it as non-numeric.
        let mut report = sample_report();
        report.shots[0].offered_objective = f64::NAN;
        assert!(lint_report(&report.to_json()).unwrap_err().contains("not a number"));
    }

    #[test]
    fn lint_catches_inconsistent_totals() {
        let doc = sample_report().to_json();
        let mut text = doc.to_string();
        text = text.replace("\"shots_accepted\":4", "\"shots_accepted\":9");
        let back = Json::parse(&text).unwrap();
        assert!(lint_report(&back).unwrap_err().contains("shots_accepted"));
    }

    #[test]
    fn html_render_is_self_contained() {
        let doc = sample_report().to_json();
        let html = render_html(&doc);
        assert!(html.starts_with("<!doctype html>"));
        assert!(html.contains("<svg"), "descent chart missing");
        assert!(html.contains("Objective descent"));
        assert!(html.contains("Shot latency"));
        assert!(!html.contains("http://"), "must not reference external assets");
        assert!(!html.contains("https://"));
    }

    #[test]
    fn disabled_sink_buffers_nothing() {
        // Executors tap record_shot unconditionally; the sink itself must
        // drop events while disabled or every default run leaks memory.
        let sink = ReportSink::new();
        sink.record_shot(10.0, 10.0, true, 3, None);
        assert!(sink.drain().is_empty());
        sink.enable();
        sink.record_shot(9.0, 9.0, false, 2, None);
        sink.disable_and_clear();
        sink.record_shot(8.0, 8.0, false, 1, None);
        assert!(sink.drain().is_empty());
    }

    #[test]
    fn sink_records_in_order_and_drains() {
        let sink = ReportSink::new();
        sink.enable();
        sink.record_shot(10.0, 10.0, true, 3, None);
        sink.record_shot(9.0, 9.0, false, 2, Some(0.5));
        let events = sink.drain();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].seq, 0);
        assert_eq!(events[1].seq, 1);
        assert!(events[1].secs.is_some());
        assert!(sink.drain().is_empty());
    }
}
