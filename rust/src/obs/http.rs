//! Minimal HTTP/1.1 responder for `GET /metrics` + `GET /healthz`, and
//! a push-gateway client for batch runs.
//!
//! Serves Prometheus text exposition from the process registry on a
//! dedicated listener (`serve --metrics-addr HOST:PORT`), independent of
//! the custom TCP protocol port so scrapers never contend with assign
//! traffic. `GET /healthz` answers a JSON health document — liveness plus
//! whatever the daemon's health callback reports (model generation,
//! swap-generation history). One request per connection
//! (`Connection: close`), headers capped at 8 KiB, anything else answered
//! 404. Shutdown follows the serve daemon's pattern: set the stop flag,
//! then self-connect to wake the blocking `accept`.
//!
//! [`push_exposition`] is the other direction: a batch `cluster` run that
//! finishes inside one scrape interval would never be scraped, so
//! `--metrics-push HOST:PORT` POSTs the final exposition to a Prometheus
//! push gateway at exit (standard `/metrics/job/<job>` path).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::json::Json;

use super::Registry;

const MAX_HEADER_BYTES: usize = 8 * 1024;

/// Health-document callback for `GET /healthz` (the serve daemon passes
/// one reporting model generation and swap history).
pub type HealthFn = Arc<dyn Fn() -> Json + Send + Sync>;

/// Handle to a running metrics listener; [`MetricsServer::shutdown`]
/// stops it and joins the accept thread.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` and serve `registry.render()` on `GET /metrics` until
    /// [`MetricsServer::shutdown`]. `/healthz` answers a plain liveness
    /// document.
    pub fn start(addr: &str, registry: &'static Registry) -> Result<MetricsServer, String> {
        Self::start_with_health(addr, registry, None)
    }

    /// [`MetricsServer::start`] with a health callback: `GET /healthz`
    /// answers its JSON document (status, generation, swap history).
    pub fn start_with_health(
        addr: &str,
        registry: &'static Registry,
        health: Option<HealthFn>,
    ) -> Result<MetricsServer, String> {
        let listener =
            TcpListener::bind(addr).map_err(|e| format!("metrics: bind {addr}: {e}"))?;
        let local = listener
            .local_addr()
            .map_err(|e| format!("metrics: local_addr: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_for_thread = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("metrics-http".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop_for_thread.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => handle_request(stream, registry, health.as_ref()),
                        Err(e) => {
                            crate::log_warn!("obs.http", "accept failed: {e}");
                        }
                    }
                }
            })
            .map_err(|e| format!("metrics: spawn listener: {e}"))?;
        crate::log_info!("obs.http", "metrics exposition listening on http://{local}/metrics");
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (useful when the caller asked for port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the listener thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        if self.handle.is_some() {
            self.stop.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(self.addr);
            if let Some(handle) = self.handle.take() {
                let _ = handle.join();
            }
        }
    }
}

fn handle_request(mut stream: TcpStream, registry: &Registry, health: Option<&HealthFn>) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    // Read until the end of the request headers; the body (none expected
    // for GET) is ignored.
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > MAX_HEADER_BYTES {
                    break;
                }
            }
            Err(_) => return,
        }
    }
    let request_line = match std::str::from_utf8(&buf) {
        Ok(text) => text.lines().next().unwrap_or("").to_string(),
        Err(_) => String::new(),
    };
    let mut parts = request_line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let response = if method == "GET" && (path == "/metrics" || path == "/metrics/") {
        let body = registry.render();
        format!(
            "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        )
    } else if method == "GET" && (path == "/healthz" || path == "/healthz/") {
        let doc = match health {
            Some(h) => h(),
            None => crate::util::json::obj(vec![("status", crate::util::json::s("ok"))]),
        };
        let body = doc.to_string() + "\n";
        format!(
            "HTTP/1.1 200 OK\r\nContent-Type: application/json; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        )
    } else {
        let body = "not found; try GET /metrics or GET /healthz\n";
        format!(
            "HTTP/1.1 404 Not Found\r\nContent-Type: text/plain; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        )
    };
    let _ = stream.write_all(response.as_bytes());
    let _ = stream.flush();
}

/// POST a Prometheus text exposition to a push gateway at
/// `addr` (`HOST:PORT`), under the standard `/metrics/job/<job>` grouping
/// path. Same hand-rolled HTTP/1.1 framing as the responder above; any
/// non-2xx status (or no status at all) is an error.
pub fn push_exposition(addr: &str, job: &str, body: &str) -> Result<(), String> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| format!("metrics-push: connect {addr}: {e}"))?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let request = format!(
        "POST /metrics/job/{job} HTTP/1.1\r\nHost: {addr}\r\n\
         Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    );
    stream
        .write_all(request.as_bytes())
        .map_err(|e| format!("metrics-push: send to {addr}: {e}"))?;
    let _ = stream.flush();
    let mut response = Vec::new();
    let mut chunk = [0u8; 512];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                response.extend_from_slice(&chunk[..n]);
                if response.len() >= MAX_HEADER_BYTES || response.windows(2).any(|w| w == b"\r\n")
                {
                    break; // the status line is all we need
                }
            }
            Err(e) => return Err(format!("metrics-push: read status from {addr}: {e}")),
        }
    }
    let status_line = std::str::from_utf8(&response)
        .ok()
        .and_then(|t| t.lines().next())
        .unwrap_or("")
        .to_string();
    let code = status_line.split_whitespace().nth(1).and_then(|c| c.parse::<u16>().ok());
    match code {
        Some(c) if (200..300).contains(&c) => Ok(()),
        Some(c) => Err(format!("metrics-push: gateway {addr} answered {c}: {status_line}")),
        None => Err(format!("metrics-push: no HTTP status from {addr}: '{status_line}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn http_get(addr: SocketAddr, path: &str) -> String {
        let mut stream = TcpStream::connect(addr).expect("connect metrics server");
        let req = format!("GET {path} HTTP/1.1\r\nHost: localhost\r\n\r\n");
        stream.write_all(req.as_bytes()).unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_404s_everything_else() {
        // A dedicated leaked registry keeps this test independent of the
        // process-wide one other tests may mutate.
        let registry: &'static Registry = Box::leak(Box::new(Registry::new()));
        registry.enable();
        registry
            .counter("http_test_total", "test counter", &[("op", "x")])
            .add(3);
        let server = MetricsServer::start("127.0.0.1:0", registry).expect("start");
        let addr = server.addr();

        let ok = http_get(addr, "/metrics");
        assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "got: {ok}");
        assert!(ok.contains("text/plain; version=0.0.4"));
        assert!(ok.contains("# TYPE http_test_total counter"));
        assert!(ok.contains("http_test_total{op=\"x\"} 3"));

        let missing = http_get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.1 404"), "got: {missing}");

        server.shutdown();
    }

    #[test]
    fn healthz_answers_default_and_callback_documents() {
        let registry: &'static Registry = Box::leak(Box::new(Registry::new()));
        let server = MetricsServer::start("127.0.0.1:0", registry).expect("start");
        let plain = http_get(server.addr(), "/healthz");
        assert!(plain.starts_with("HTTP/1.1 200 OK\r\n"), "got: {plain}");
        assert!(plain.contains("\"status\":\"ok\""));
        server.shutdown();

        let health: HealthFn = Arc::new(|| {
            crate::util::json::obj(vec![
                ("status", crate::util::json::s("ok")),
                ("generation", crate::util::json::num(7.0)),
            ])
        });
        let server = MetricsServer::start_with_health("127.0.0.1:0", registry, Some(health))
            .expect("start with health");
        let body = http_get(server.addr(), "/healthz");
        assert!(body.contains("application/json"), "got: {body}");
        assert!(body.contains("\"generation\":7"), "got: {body}");
        server.shutdown();
    }

    #[test]
    fn push_exposition_posts_and_checks_status() {
        use std::io::BufRead;
        // A one-shot fake gateway: accept, read the request, answer 202.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let seen = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = std::io::BufReader::new(stream);
            let mut request_line = String::new();
            reader.read_line(&mut request_line).unwrap();
            let mut len = 0usize;
            loop {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
                    len = v.trim().parse().unwrap();
                }
                if line == "\r\n" {
                    break;
                }
            }
            let mut body = vec![0u8; len];
            reader.read_exact(&mut body).unwrap();
            let mut stream = reader.into_inner();
            stream
                .write_all(b"HTTP/1.1 202 Accepted\r\nContent-Length: 0\r\n\r\n")
                .unwrap();
            (request_line, String::from_utf8(body).unwrap())
        });
        let exposition = "# TYPE push_test_total counter\npush_test_total 5\n";
        push_exposition(&addr.to_string(), "bigmeans", exposition).expect("push ok");
        let (request_line, body) = seen.join().unwrap();
        assert!(request_line.starts_with("POST /metrics/job/bigmeans HTTP/1.1"));
        assert_eq!(body, exposition);

        // A gateway that answers 500 must surface as an error.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let mut sink = [0u8; 1024];
            let _ = stream.read(&mut sink);
            let _ = stream.write_all(b"HTTP/1.1 500 Internal Server Error\r\n\r\n");
        });
        let err = push_exposition(&addr.to_string(), "bigmeans", "x 1\n").unwrap_err();
        assert!(err.contains("500"), "got: {err}");
    }
}
