//! Minimal HTTP/1.1 responder for `GET /metrics`.
//!
//! Serves Prometheus text exposition from the process registry on a
//! dedicated listener (`serve --metrics-addr HOST:PORT`), independent of
//! the custom TCP protocol port so scrapers never contend with assign
//! traffic. One request per connection (`Connection: close`), headers
//! capped at 8 KiB, anything but `GET /metrics` answered 404. Shutdown
//! follows the serve daemon's pattern: set the stop flag, then self-
//! connect to wake the blocking `accept`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::Registry;

const MAX_HEADER_BYTES: usize = 8 * 1024;

/// Handle to a running metrics listener; [`MetricsServer::shutdown`]
/// stops it and joins the accept thread.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` and serve `registry.render()` on `GET /metrics` until
    /// [`MetricsServer::shutdown`].
    pub fn start(addr: &str, registry: &'static Registry) -> Result<MetricsServer, String> {
        let listener =
            TcpListener::bind(addr).map_err(|e| format!("metrics: bind {addr}: {e}"))?;
        let local = listener
            .local_addr()
            .map_err(|e| format!("metrics: local_addr: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_for_thread = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("metrics-http".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop_for_thread.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => handle_request(stream, registry),
                        Err(e) => {
                            crate::log_warn!("obs.http", "accept failed: {e}");
                        }
                    }
                }
            })
            .map_err(|e| format!("metrics: spawn listener: {e}"))?;
        crate::log_info!("obs.http", "metrics exposition listening on http://{local}/metrics");
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (useful when the caller asked for port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the listener thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        if self.handle.is_some() {
            self.stop.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(self.addr);
            if let Some(handle) = self.handle.take() {
                let _ = handle.join();
            }
        }
    }
}

fn handle_request(mut stream: TcpStream, registry: &Registry) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    // Read until the end of the request headers; the body (none expected
    // for GET) is ignored.
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > MAX_HEADER_BYTES {
                    break;
                }
            }
            Err(_) => return,
        }
    }
    let request_line = match std::str::from_utf8(&buf) {
        Ok(text) => text.lines().next().unwrap_or("").to_string(),
        Err(_) => String::new(),
    };
    let mut parts = request_line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let response = if method == "GET" && (path == "/metrics" || path == "/metrics/") {
        let body = registry.render();
        format!(
            "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        )
    } else {
        let body = "not found; try GET /metrics\n";
        format!(
            "HTTP/1.1 404 Not Found\r\nContent-Type: text/plain; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        )
    };
    let _ = stream.write_all(response.as_bytes());
    let _ = stream.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn http_get(addr: SocketAddr, path: &str) -> String {
        let mut stream = TcpStream::connect(addr).expect("connect metrics server");
        let req = format!("GET {path} HTTP/1.1\r\nHost: localhost\r\n\r\n");
        stream.write_all(req.as_bytes()).unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_404s_everything_else() {
        // A dedicated leaked registry keeps this test independent of the
        // process-wide one other tests may mutate.
        let registry: &'static Registry = Box::leak(Box::new(Registry::new()));
        registry.enable();
        registry
            .counter("http_test_total", "test counter", &[("op", "x")])
            .add(3);
        let server = MetricsServer::start("127.0.0.1:0", registry).expect("start");
        let addr = server.addr();

        let ok = http_get(addr, "/metrics");
        assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "got: {ok}");
        assert!(ok.contains("text/plain; version=0.0.4"));
        assert!(ok.contains("# TYPE http_test_total counter"));
        assert!(ok.contains("http_test_total{op=\"x\"} 3"));

        let missing = http_get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.1 404"), "got: {missing}");

        server.shutdown();
    }
}
