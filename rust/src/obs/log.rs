//! Leveled, timestamped stderr logging — the structured replacement for
//! the ad-hoc `eprintln!` progress and warning lines.
//!
//! One line per record: `2026-08-07T12:34:56.789Z WARN serve.watcher:
//! message`, machine-parseable (fixed field order, UTC, target-tagged).
//! The max level is a relaxed `AtomicU8`, resolved once at startup from
//! the `--log-level` flag, else the `BIGMEANS_LOG` env var, else `info`.
//! The [`crate::log_warn!`]-family macros gate the formatting cost on
//! [`enabled`], so suppressed records cost one relaxed load.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Resolve and install the max level: explicit flag value, else the
/// `BIGMEANS_LOG` env var, else `info`. Returns an error for an
/// unrecognised level token (listing the accepted ones).
pub fn init(flag: Option<&str>) -> Result<(), String> {
    let token = match flag {
        Some(t) => Some(t.to_string()),
        None => std::env::var("BIGMEANS_LOG").ok(),
    };
    let level = match token {
        None => Level::Info,
        Some(t) => Level::parse(&t).ok_or_else(|| {
            format!("bad log level '{t}': expected error|warn|info|debug|trace")
        })?,
    };
    set_max_level(level);
    Ok(())
}

/// Install the max level directly.
pub fn set_max_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current max level.
pub fn max_level() -> Level {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Whether records at `level` are currently emitted.
#[inline]
pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record (already level-gated by the macros). Warn/error
/// records are also tapped into the flight recorder when it is enabled,
/// so a crash dump carries the run's recent complaints.
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    let ts = timestamp_utc();
    if level <= Level::Warn {
        let recorder = crate::obs::recorder::recorder();
        if recorder.enabled() {
            recorder.record_log(&ts, level.name(), target, &format!("{args}"));
        }
    }
    eprintln!("{ts} {:<5} {target}: {args}", level.name());
}

/// `YYYY-MM-DDTHH:MM:SS.mmmZ` from the system clock, hand-rolled (no
/// chrono offline). Days-to-civil conversion per Howard Hinnant's
/// `civil_from_days` algorithm.
pub fn timestamp_utc() -> String {
    let dur = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let secs = dur.as_secs();
    let millis = dur.subsec_millis();
    let days = (secs / 86_400) as i64;
    let tod = secs % 86_400;
    let (h, mi, s) = (tod / 3600, (tod % 3600) / 60, tod % 60);
    let (y, m, d) = civil_from_days(days);
    format!("{y:04}-{m:02}-{d:02}T{h:02}:{mi:02}:{s:02}.{millis:03}Z")
}

fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = (z - era * 146_097) as u64;
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe as i64 + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32;
    (if m <= 2 { y + 1 } else { y }, m, d)
}

/// Log at an explicit level: `log_at!(Level::Warn, "target", "...", ..)`.
#[macro_export]
macro_rules! log_at {
    ($level:expr, $target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($level) {
            $crate::obs::log::log($level, $target, format_args!($($arg)*));
        }
    };
}

/// `log_error!("target", "fmt", args...)`.
#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::log_at!($crate::obs::log::Level::Error, $target, $($arg)*)
    };
}

/// `log_warn!("target", "fmt", args...)`.
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::log_at!($crate::obs::log::Level::Warn, $target, $($arg)*)
    };
}

/// `log_info!("target", "fmt", args...)`.
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::log_at!($crate::obs::log::Level::Info, $target, $($arg)*)
    };
}

/// `log_debug!("target", "fmt", args...)`.
#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::log_at!($crate::obs::log::Level::Debug, $target, $($arg)*)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("WARNING"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn civil_from_days_known_dates() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(19_723), (2024, 1, 1)); // leap year
        assert_eq!(civil_from_days(19_782), (2024, 2, 29));
        assert_eq!(civil_from_days(20_672), (2026, 8, 7));
    }
}
