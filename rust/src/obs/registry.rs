//! The lock-free metric registry: atomic counters, gauges, and log2
//! histograms behind Prometheus-style labeled families.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are cheap `Arc` clones
//! of the registered cell; recording is one relaxed-atomic branch on the
//! registry's enabled flag plus one relaxed RMW — observers never take a
//! lock on the hot path and never participate in the computation they
//! watch, so the bit-identicality contracts survive instrumentation by
//! construction. The family map itself is a `Mutex<BTreeMap>`, touched
//! only at registration and render time.
//!
//! [`Registry::render`] emits the Prometheus text exposition format
//! (version 0.0.4): `# HELP` / `# TYPE` comment lines followed by the
//! family's samples, histograms as cumulative `_bucket{le=...}` series
//! plus `_sum` / `_count`. Output is deterministic (families and series
//! sorted), which the exposition lint in [`super::lint`] leans on.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::sync::lock_recover;

/// Log2-bucketed latency histogram: lock-free to record, coarse (power
/// of two upper bounds) to read. `buckets[i]` counts observations with
/// `2^(i-1) < micros <= 2^i` (bucket 0 holds sub-microsecond ones), so a
/// quantile estimate is the upper bound of the bucket holding the target
/// rank — always `>=` the true quantile and at most 2× above it (the
/// bound the property tests in `tests/property_obs.rs` enforce). Shared
/// by the serve daemon's per-op stats and the registry's histograms.
pub struct Log2Histogram {
    buckets: [AtomicU64; 64],
    sum_us: AtomicU64,
}

impl Default for Log2Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Log2Histogram {
    pub fn new() -> Log2Histogram {
        Log2Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Bucket index for a microsecond value.
    #[inline]
    pub fn bucket_index(us: u64) -> usize {
        if us == 0 {
            0
        } else {
            (64 - us.leading_zeros() as usize).min(63)
        }
    }

    /// Upper bound (seconds) of bucket `i`.
    #[inline]
    pub fn bucket_upper_secs(i: usize) -> f64 {
        if i >= 63 {
            f64::INFINITY
        } else {
            (1u64 << i) as f64 * 1e-6
        }
    }

    #[inline]
    pub fn record(&self, elapsed: Duration) {
        self.record_us(elapsed.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    #[inline]
    pub fn record_us(&self, us: u64) {
        self.buckets[Self::bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Snapshot of the per-bucket counts.
    pub fn counts(&self) -> [u64; 64] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Total observations.
    pub fn total(&self) -> u64 {
        self.counts().iter().sum()
    }

    /// Sum of observed values in seconds.
    pub fn sum_secs(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 * 1e-6
    }

    /// Upper-bound latency (seconds) of the bucket holding quantile `q`.
    pub fn percentile_secs(&self, q: f64) -> f64 {
        Self::percentile_secs_merged(&[self], q)
    }

    /// Quantile over the union of several histograms (e.g. the serve
    /// daemon's assign + score ops merged for the backward-compatible
    /// top-level percentiles).
    pub fn percentile_secs_merged(hists: &[&Log2Histogram], q: f64) -> f64 {
        let mut counts = [0u64; 64];
        for h in hists {
            for (acc, c) in counts.iter_mut().zip(h.counts()) {
                *acc += c;
            }
        }
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << i) as f64 * 1e-6;
            }
        }
        (1u64 << 63) as f64 * 1e-6
    }
}

/// Metric kind, mirroring the Prometheus `# TYPE` vocabulary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn name(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

enum Cell {
    Counter(Arc<AtomicU64>),
    /// f64 bits in an AtomicU64.
    Gauge(Arc<AtomicU64>),
    Histogram(Arc<Log2Histogram>),
}

struct Family {
    help: String,
    kind: Kind,
    label_names: Vec<String>,
    series: BTreeMap<Vec<String>, Cell>,
}

/// A monotone counter handle. Recording is a relaxed enabled-check plus a
/// relaxed `fetch_add`; cloning shares the underlying cell.
#[derive(Clone)]
pub struct Counter {
    enabled: Arc<AtomicBool>,
    cell: Arc<AtomicU64>,
}

impl Counter {
    #[inline]
    pub fn add(&self, n: u64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.cell.fetch_add(n, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn value(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }
}

/// A gauge handle: last-write-wins f64.
#[derive(Clone)]
pub struct Gauge {
    enabled: Arc<AtomicBool>,
    cell: Arc<AtomicU64>,
}

impl Gauge {
    #[inline]
    pub fn set(&self, v: f64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.cell.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn value(&self) -> f64 {
        f64::from_bits(self.cell.load(Ordering::Relaxed))
    }
}

/// A histogram handle over a shared [`Log2Histogram`].
#[derive(Clone)]
pub struct Histogram {
    enabled: Arc<AtomicBool>,
    hist: Arc<Log2Histogram>,
}

impl Histogram {
    #[inline]
    pub fn observe(&self, elapsed: Duration) {
        if self.enabled.load(Ordering::Relaxed) {
            self.hist.record(elapsed);
        }
    }

    /// The shared histogram (for percentile reads in tests/telemetry).
    pub fn inner(&self) -> &Log2Histogram {
        &self.hist
    }
}

/// A process-wide (or test-local) family registry.
pub struct Registry {
    enabled: Arc<AtomicBool>,
    families: Mutex<BTreeMap<String, Family>>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    /// A fresh registry, **disabled**: every handle it vends is a no-op
    /// until [`Registry::enable`] flips the shared flag.
    pub fn new() -> Registry {
        Registry {
            enabled: Arc::new(AtomicBool::new(false)),
            families: Mutex::new(BTreeMap::new()),
        }
    }

    /// Start recording. Values accumulated before enabling stay zero.
    pub fn enable(&self) {
        self.enabled.store(true, Ordering::Relaxed);
    }

    /// Stop recording (bench A/B rows); accumulated values are kept.
    pub fn disable(&self) {
        self.enabled.store(false, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Register (or look up) a counter series. `labels` are
    /// `(name, value)` pairs; re-registering the same name with a
    /// different kind or label-name set panics — that is a programming
    /// error the exposition lint would otherwise flag at scrape time.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        let cell = self.series(name, help, Kind::Counter, labels, |_| {
            Cell::Counter(Arc::new(AtomicU64::new(0)))
        });
        match cell {
            Cell::Counter(c) => Counter { enabled: Arc::clone(&self.enabled), cell: c },
            _ => unreachable!("kind checked in series()"),
        }
    }

    /// Register (or look up) a gauge series.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        let cell = self.series(name, help, Kind::Gauge, labels, |_| {
            Cell::Gauge(Arc::new(AtomicU64::new(0f64.to_bits())))
        });
        match cell {
            Cell::Gauge(c) => Gauge { enabled: Arc::clone(&self.enabled), cell: c },
            _ => unreachable!("kind checked in series()"),
        }
    }

    /// Register (or look up) a histogram series.
    pub fn histogram(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Histogram {
        let cell = self.series(name, help, Kind::Histogram, labels, |_| {
            Cell::Histogram(Arc::new(Log2Histogram::new()))
        });
        match cell {
            Cell::Histogram(h) => {
                Histogram { enabled: Arc::clone(&self.enabled), hist: h }
            }
            _ => unreachable!("kind checked in series()"),
        }
    }

    fn series(
        &self,
        name: &str,
        help: &str,
        kind: Kind,
        labels: &[(&str, &str)],
        make: impl FnOnce(&[(&str, &str)]) -> Cell,
    ) -> Cell {
        let label_names: Vec<String> = labels.iter().map(|(k, _)| k.to_string()).collect();
        let label_values: Vec<String> = labels.iter().map(|(_, v)| v.to_string()).collect();
        let _section = super::section::enter();
        let mut fams = lock_recover(&self.families);
        let fam = fams.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind,
            label_names: label_names.clone(),
            series: BTreeMap::new(),
        });
        assert_eq!(
            fam.kind, kind,
            "metric family '{name}' re-registered with a different kind"
        );
        assert_eq!(
            fam.label_names, label_names,
            "metric family '{name}' re-registered with different label names"
        );
        let cell = fam.series.entry(label_values).or_insert_with(|| make(labels));
        match cell {
            Cell::Counter(c) => Cell::Counter(Arc::clone(c)),
            Cell::Gauge(c) => Cell::Gauge(Arc::clone(c)),
            Cell::Histogram(h) => Cell::Histogram(Arc::clone(h)),
        }
    }

    /// Render the Prometheus text exposition (format version 0.0.4).
    pub fn render(&self) -> String {
        let _section = super::section::enter();
        let fams = lock_recover(&self.families);
        let mut out = String::new();
        for (name, fam) in fams.iter() {
            let _ = writeln!(out, "# HELP {name} {}", escape_help(&fam.help));
            let _ = writeln!(out, "# TYPE {name} {}", fam.kind.name());
            for (values, cell) in &fam.series {
                let labels = format_labels(&fam.label_names, values);
                match cell {
                    Cell::Counter(c) => {
                        let _ =
                            writeln!(out, "{name}{labels} {}", c.load(Ordering::Relaxed));
                    }
                    Cell::Gauge(c) => {
                        let v = f64::from_bits(c.load(Ordering::Relaxed));
                        let _ = writeln!(out, "{name}{labels} {}", format_value(v));
                    }
                    Cell::Histogram(h) => {
                        render_histogram(&mut out, name, &fam.label_names, values, h);
                    }
                }
            }
        }
        out
    }
}

/// Cumulative `_bucket` series up to the highest non-empty bucket, then
/// `+Inf`, `_sum`, `_count` — the standard Prometheus histogram shape.
fn render_histogram(
    out: &mut String,
    name: &str,
    label_names: &[String],
    values: &[String],
    h: &Log2Histogram,
) {
    let counts = h.counts();
    let highest = counts.iter().rposition(|&c| c > 0);
    let mut cumulative = 0u64;
    if let Some(hi) = highest {
        for (i, &c) in counts.iter().enumerate().take(hi + 1) {
            cumulative += c;
            let le = format_value(Log2Histogram::bucket_upper_secs(i));
            let labels = format_labels_with(label_names, values, &[("le", &le)]);
            let _ = writeln!(out, "{name}_bucket{labels} {cumulative}");
        }
    }
    let inf_labels = format_labels_with(label_names, values, &[("le", "+Inf")]);
    let _ = writeln!(out, "{name}_bucket{inf_labels} {cumulative}");
    let plain = format_labels(label_names, values);
    let _ = writeln!(out, "{name}_sum{plain} {}", format_value(h.sum_secs()));
    let _ = writeln!(out, "{name}_count{plain} {cumulative}");
}

fn format_value(v: f64) -> String {
    if v.is_infinite() {
        if v > 0.0 { "+Inf".into() } else { "-Inf".into() }
    } else if v.is_nan() {
        "NaN".into()
    } else {
        format!("{v}")
    }
}

fn format_labels(names: &[String], values: &[String]) -> String {
    format_labels_with(names, values, &[])
}

fn format_labels_with(names: &[String], values: &[String], extra: &[(&str, &str)]) -> String {
    if names.is_empty() && extra.is_empty() {
        return String::new();
    }
    let mut parts = Vec::with_capacity(names.len() + extra.len());
    for (k, v) in names.iter().zip(values) {
        parts.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    for (k, v) in extra {
        parts.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    format!("{{{}}}", parts.join(","))
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn escape_help(v: &str) -> String {
    v.replace('\\', "\\\\").replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_handles_are_noops() {
        let reg = Registry::new();
        let c = reg.counter("t_total", "t", &[]);
        c.add(5);
        assert_eq!(c.value(), 0);
        reg.enable();
        c.add(5);
        assert_eq!(c.value(), 5);
    }

    #[test]
    fn series_are_shared_by_name_and_labels() {
        let reg = Registry::new();
        reg.enable();
        let a = reg.counter("x_total", "x", &[("op", "assign")]);
        let b = reg.counter("x_total", "x", &[("op", "assign")]);
        let other = reg.counter("x_total", "x", &[("op", "score")]);
        a.inc();
        b.inc();
        other.add(7);
        assert_eq!(a.value(), 2);
        assert_eq!(other.value(), 7);
    }

    #[test]
    fn render_emits_help_type_then_samples() {
        let reg = Registry::new();
        reg.enable();
        reg.counter("b_total", "counts b", &[("op", "x")]).add(3);
        reg.gauge("a_gauge", "gauge a", &[]).set(1.5);
        let h = reg.histogram("c_seconds", "hist c", &[]);
        h.observe(Duration::from_micros(3));
        let text = reg.render();
        // Families sorted; HELP precedes TYPE precedes samples.
        let a = text.find("# HELP a_gauge").unwrap();
        let b = text.find("# HELP b_total").unwrap();
        assert!(a < b);
        assert!(text.contains("# TYPE b_total counter"));
        assert!(text.contains("b_total{op=\"x\"} 3"));
        assert!(text.contains("a_gauge 1.5"));
        assert!(text.contains("# TYPE c_seconds histogram"));
        // 3µs lands in bucket 2 (upper bound 4µs = 4e-6 s).
        assert!(text.contains("c_seconds_bucket{le=\"0.000004\"} 1"));
        assert!(text.contains("c_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("c_seconds_count 1"));
    }

    #[test]
    fn histogram_bucket_math() {
        assert_eq!(Log2Histogram::bucket_index(0), 0);
        assert_eq!(Log2Histogram::bucket_index(1), 1);
        assert_eq!(Log2Histogram::bucket_index(2), 2);
        assert_eq!(Log2Histogram::bucket_index(3), 2);
        assert_eq!(Log2Histogram::bucket_index(4), 3);
        assert_eq!(Log2Histogram::bucket_index(u64::MAX), 63);
    }

    #[test]
    fn merged_percentile_spans_histograms() {
        let a = Log2Histogram::new();
        let b = Log2Histogram::new();
        for _ in 0..99 {
            a.record_us(1); // bucket 1, bound 2µs
        }
        b.record_us(1_000_000); // bucket 20, bound ~2.1s
        let p50 = Log2Histogram::percentile_secs_merged(&[&a, &b], 0.50);
        let p99 = Log2Histogram::percentile_secs_merged(&[&a, &b], 0.999);
        assert!(p50 <= 4e-6, "p50 {p50}");
        assert!(p99 >= 1.0, "p99 {p99}");
    }
}
