//! A small Prometheus text-exposition lint, used by the `metrics-lint`
//! CLI subcommand and CI's scrape gate.
//!
//! Checks (per exposition):
//! * every sample belongs to a family announced by a preceding `# TYPE`;
//! * `# TYPE` appears at most once per family, after its `# HELP`;
//! * a family's lines are contiguous (no family is split or repeated);
//! * sample values parse as floats (`+Inf`/`-Inf`/`NaN` accepted);
//! * histogram `_bucket` series are cumulative (non-decreasing in `le`
//!   order as emitted) and agree with `_count`.
//!
//! [`check_monotone`] compares two scrapes: every counter series (and
//! histogram `_bucket`/`_count`/`_sum`) present in both must not have
//! decreased — the property Prometheus rate() relies on.

use std::collections::BTreeMap;

/// Parsed exposition: family name → (type token, series name+labels →
/// value, in emission order).
pub struct Exposition {
    pub families: BTreeMap<String, FamilyLint>,
    pub samples: usize,
}

pub struct FamilyLint {
    pub kind: String,
    /// Series in emission order: (full sample name incl. labels, value).
    pub series: Vec<(String, f64)>,
}

/// Map a sample name to its family, folding histogram suffixes.
fn family_of<'a>(name: &'a str, declared: &BTreeMap<String, FamilyLint>) -> &'a str {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suffix) {
            if declared.get(base).is_some_and(|f| f.kind == "histogram") {
                return base;
            }
        }
    }
    name
}

fn parse_value(tok: &str) -> Result<f64, String> {
    match tok {
        "+Inf" => Ok(f64::INFINITY),
        "-Inf" => Ok(f64::NEG_INFINITY),
        "NaN" => Ok(f64::NAN),
        t => t.parse::<f64>().map_err(|_| format!("bad sample value '{t}'")),
    }
}

/// Lint one exposition document. Returns the parsed structure so callers
/// can run [`check_monotone`] across two scrapes.
pub fn lint_exposition(text: &str) -> Result<Exposition, String> {
    let mut families: BTreeMap<String, FamilyLint> = BTreeMap::new();
    let mut helped: Vec<String> = Vec::new();
    // Families whose sample block has ended; reappearing is an error.
    let mut closed: Vec<String> = Vec::new();
    let mut current: Option<String> = None;
    let mut samples = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let err = |msg: String| format!("line {}: {msg}", lineno + 1);
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap_or_default().to_string();
            if name.is_empty() {
                return Err(err("HELP line without a metric name".into()));
            }
            helped.push(name);
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let (name, kind) = (
                it.next().unwrap_or_default().to_string(),
                it.next().unwrap_or_default().to_string(),
            );
            let known = ["counter", "gauge", "histogram", "summary", "untyped"];
            if !known.contains(&kind.as_str()) {
                return Err(err(format!("unknown TYPE '{kind}' for '{name}'")));
            }
            if families.contains_key(&name) {
                return Err(err(format!("duplicate TYPE line for family '{name}'")));
            }
            if !helped.contains(&name) {
                return Err(err(format!("TYPE for '{name}' without a preceding HELP")));
            }
            families.insert(name.clone(), FamilyLint { kind, series: Vec::new() });
            if let Some(prev) = current.replace(name) {
                closed.push(prev);
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // free-form comment
        }
        // Sample line: name[{labels}] value [timestamp]
        let name_end = line.find(['{', ' ']).ok_or_else(|| {
            err(format!("malformed sample line '{line}'"))
        })?;
        let bare_name = &line[..name_end];
        let family = family_of(bare_name, &families).to_string();
        if !families.contains_key(&family) {
            return Err(err(format!(
                "sample '{bare_name}' before its family's TYPE line"
            )));
        }
        if closed.contains(&family) {
            return Err(err(format!(
                "family '{family}' reappears after other families' samples"
            )));
        }
        if current.as_deref() != Some(&family) {
            return Err(err(format!(
                "sample '{bare_name}' interleaved into family '{}'",
                current.as_deref().unwrap_or("<none>")
            )));
        }
        let (series, value_part) = match line[name_end..].strip_prefix('{') {
            Some(rest) => {
                let close = rest.find('}').ok_or_else(|| {
                    err(format!("unterminated label set in '{line}'"))
                })?;
                (&line[..name_end + 1 + close + 1], rest[close + 1..].trim())
            }
            None => (bare_name, line[name_end..].trim()),
        };
        let value_tok = value_part.split_whitespace().next().ok_or_else(|| {
            err(format!("sample '{bare_name}' has no value"))
        })?;
        let value = parse_value(value_tok).map_err(err)?;
        let fam = families.get_mut(&family).expect("family presence checked");
        fam.series.push((series.to_string(), value));
        samples += 1;
    }
    // Histogram internal consistency: buckets cumulative, +Inf == _count.
    for (name, fam) in &families {
        if fam.kind != "histogram" {
            continue;
        }
        let mut last_bucket: Option<(String, f64)> = None;
        let mut inf: BTreeMap<String, f64> = BTreeMap::new();
        for (series, value) in &fam.series {
            if let Some(rest) = series.strip_prefix(name.as_str()) {
                if rest.starts_with("_bucket") {
                    let base = strip_le_label(series);
                    if let Some((prev_base, prev)) = &last_bucket {
                        if *prev_base == base && value < prev {
                            return Err(format!(
                                "histogram '{name}': bucket counts not cumulative \
                                 at {series}"
                            ));
                        }
                    }
                    if series.contains("le=\"+Inf\"") {
                        inf.insert(base.clone(), *value);
                    }
                    last_bucket = Some((base, *value));
                } else if rest.starts_with("_count") {
                    let base = series.clone();
                    let key = base
                        .strip_prefix(name.as_str())
                        .and_then(|r| r.strip_prefix("_count"))
                        .unwrap_or("")
                        .to_string();
                    let inf_key = inf.keys().find(|k| le_base_matches(k, &key));
                    if let Some(ik) = inf_key {
                        if (inf[ik] - value).abs() > 0.0 {
                            return Err(format!(
                                "histogram '{name}': _count {value} disagrees with \
                                 +Inf bucket {}",
                                inf[ik]
                            ));
                        }
                    }
                }
            }
        }
    }
    Ok(Exposition { families, samples })
}

/// The `_bucket` series identity with its `le` label removed, so
/// cumulativity is checked within one label set.
fn strip_le_label(series: &str) -> String {
    let mut out = String::with_capacity(series.len());
    let mut rest = series;
    while let Some(pos) = rest.find("le=\"") {
        out.push_str(&rest[..pos]);
        let after = &rest[pos + 4..];
        match after.find('"') {
            Some(end) => rest = after[end + 1..].trim_start_matches(','),
            None => return out,
        }
    }
    out.push_str(rest);
    out.replace(",}", "}").replace("{}", "")
}

fn le_base_matches(bucket_base: &str, count_labels: &str) -> bool {
    // bucket_base is "name_bucket{labels}" sans le; count_labels is the
    // label suffix of the _count series. Loose match: same label suffix.
    bucket_base.ends_with(count_labels)
        || (count_labels.is_empty() && !bucket_base.contains('{'))
}

/// Counter monotonicity across two scrapes: every counter (and histogram
/// `_bucket`/`_count`/`_sum`) series present in both must not decrease.
pub fn check_monotone(first: &Exposition, second: &Exposition) -> Result<usize, String> {
    let mut checked = 0usize;
    for (name, fam_a) in &first.families {
        let Some(fam_b) = second.families.get(name) else { continue };
        if fam_a.kind != "counter" && fam_a.kind != "histogram" {
            continue;
        }
        let a: BTreeMap<&str, f64> =
            fam_a.series.iter().map(|(s, v)| (s.as_str(), *v)).collect();
        for (series, vb) in &fam_b.series {
            if let Some(va) = a.get(series.as_str()) {
                if vb < va {
                    return Err(format!(
                        "counter '{series}' went backwards: {va} -> {vb}"
                    ));
                }
                checked += 1;
            }
        }
    }
    Ok(checked)
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "\
# HELP a_total counts a
# TYPE a_total counter
a_total{op=\"x\"} 3
a_total{op=\"y\"} 4
# HELP h_seconds hist
# TYPE h_seconds histogram
h_seconds_bucket{le=\"0.001\"} 2
h_seconds_bucket{le=\"+Inf\"} 5
h_seconds_sum 0.25
h_seconds_count 5
";

    #[test]
    fn accepts_well_formed_exposition() {
        let e = lint_exposition(GOOD).unwrap();
        assert_eq!(e.samples, 6);
        assert_eq!(e.families["a_total"].kind, "counter");
        assert_eq!(e.families["h_seconds"].kind, "histogram");
    }

    #[test]
    fn rejects_sample_before_type() {
        let bad = "a_total 3\n";
        assert!(lint_exposition(bad).unwrap_err().contains("TYPE"));
    }

    #[test]
    fn rejects_duplicate_family() {
        let bad = "\
# HELP a_total x
# TYPE a_total counter
a_total 1
# HELP a_total x
# TYPE a_total counter
a_total 2
";
        assert!(lint_exposition(bad).unwrap_err().contains("duplicate"));
    }

    #[test]
    fn rejects_split_family() {
        let bad = "\
# HELP a_total x
# TYPE a_total counter
a_total{op=\"x\"} 1
# HELP b_total y
# TYPE b_total counter
b_total 1
a_total{op=\"y\"} 2
";
        assert!(lint_exposition(bad).unwrap_err().contains("reappears"));
    }

    #[test]
    fn rejects_non_cumulative_buckets() {
        let bad = "\
# HELP h_seconds x
# TYPE h_seconds histogram
h_seconds_bucket{le=\"0.001\"} 5
h_seconds_bucket{le=\"+Inf\"} 3
h_seconds_sum 1
h_seconds_count 3
";
        assert!(lint_exposition(bad).unwrap_err().contains("cumulative"));
    }

    #[test]
    fn monotone_check_catches_regressions() {
        let a = lint_exposition(GOOD).unwrap();
        let b = lint_exposition(&GOOD.replace("a_total{op=\"x\"} 3", "a_total{op=\"x\"} 9"))
            .unwrap();
        assert!(check_monotone(&a, &b).unwrap() > 0);
        assert!(check_monotone(&b, &a).unwrap_err().contains("backwards"));
    }
}
