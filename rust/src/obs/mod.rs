//! Zero-dependency observability: metrics, tracing, and leveled logging.
//!
//! Three pillars, all observers of the computation and never participants
//! in it (bit-identicality contracts hold with everything enabled):
//!
//! * [`registry`] — a process-wide lock-free metric registry (atomic
//!   counters, gauges, log2 histograms) with labeled families, rendered
//!   as Prometheus text exposition. The process singleton is [`metrics`];
//!   it starts disabled, so every handle is a branch-on-relaxed-atomic
//!   no-op until `--metrics-out` / `--metrics-addr` enables it.
//! * [`trace`] — shot-lifecycle spans in Chrome trace-event JSON
//!   (`--trace out.trace.json`), ring-buffered per thread, flushed at
//!   exit. Singleton: [`tracer`].
//! * [`log`] — leveled, timestamped, target-tagged stderr records
//!   (`--log-level`, `BIGMEANS_LOG`) replacing ad-hoc `eprintln!`.
//!
//! Two diagnostics rungs sit on top: [`recorder`] — an always-on bounded
//! flight recorder (recent spans, warn/error records, metric snapshots)
//! dumped on panic/SIGTERM/demand — and [`report`] — versioned per-run
//! JSON reports (`cluster --report`) rendered to self-contained HTML by
//! the `report` subcommand.
//!
//! [`lint`] validates exposition documents (CI's scrape gate) and
//! [`http`] serves `GET /metrics` + `GET /healthz` for
//! `serve --metrics-addr`, plus the push-gateway client
//! ([`http::push_exposition`]) for batch runs shorter than a scrape
//! interval.
//!
//! The full metric catalogue lives in `docs/OBSERVABILITY.md`.

pub mod http;
pub mod lint;
pub mod log;
pub mod recorder;
pub mod registry;
pub mod report;
pub mod trace;

use std::sync::OnceLock;

/// Thread-local marker for "this thread currently holds an obs lock"
/// (tracer state/shards, the registry family map, recorder bookkeeping).
/// The panic hook consults it before flushing: a panic raised *inside*
/// one of those critical sections still holds the lock on the panicking
/// thread, and re-taking a non-reentrant mutex from the hook would
/// deadlock the process instead of letting it die with the message.
pub(crate) mod section {
    use std::cell::Cell;

    thread_local! {
        static DEPTH: Cell<u32> = const { Cell::new(0) };
    }

    /// RAII marker: depth > 0 while any guard on this thread is live.
    pub(crate) struct Guard;

    pub(crate) fn enter() -> Guard {
        DEPTH.with(|d| d.set(d.get() + 1));
        Guard
    }

    impl Drop for Guard {
        fn drop(&mut self) {
            DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        }
    }

    /// Whether the current thread is inside an obs lock section.
    pub(crate) fn active() -> bool {
        DEPTH.with(|d| d.get()) > 0
    }
}

pub use http::MetricsServer;
pub use recorder::{install_crash_handlers, recorder, Recorder};
pub use registry::{Counter, Gauge, Histogram, Kind, Log2Histogram, Registry};
pub use report::{report_sink, ReportSink, RunReport};
pub use trace::{tracer, Span, Tracer};

/// The process-wide metric registry. Disabled until [`Registry::enable`];
/// handles registered while disabled record nothing.
pub fn metrics() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::new)
}

/// Pre-register the core families for an engine so a scrape taken before
/// any traffic (or any shot) still exposes them with zero values — the
/// serve daemon calls this at boot with its model's engine and ISA.
pub fn register_core(engine: &str, isa: &str) {
    let m = metrics();
    let eng = [("engine", engine), ("isa", isa)];
    m.counter(
        "bigmeans_distance_evals_total",
        "Exact point-to-centroid distance evaluations (paper n_d)",
        &eng,
    );
    m.counter(
        "bigmeans_pruned_evals_total",
        "Distance evaluations avoided by bound-based pruning",
        &eng,
    );
    m.counter(
        "bigmeans_pruned_blocks_total",
        "Blocks skipped whole by bounding-box pruning in the final pass",
        &[],
    );
    m.counter(
        "bigmeans_hybrid_switches_total",
        "Hybrid engine switches between Elkan and rescan strategies",
        &[("engine", engine)],
    );
    m.histogram(
        "bigmeans_shot_duration_seconds",
        "Wall time of one Big-means shot (sample, reseed, local search)",
        &[("engine", engine)],
    );
}
