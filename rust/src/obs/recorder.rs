//! Always-on bounded flight recorder: the last few seconds of a run,
//! dumpable at the moment of death.
//!
//! Metrics answer "how much", traces answer "where did the time go" — but
//! both are lost (or were never enabled) when a process dies mid-run. The
//! recorder keeps a bounded ring of the most recent span completions, every
//! warn/error log record, and periodic metric snapshots, so a panic hook,
//! the SIGTERM watcher thread, or a serve `dump-diagnostics` request can
//! write one diagnostics JSON naming the span that was open when the world
//! ended.
//!
//! Contracts (same as the rest of `obs`, gated by `tests/property_obs.rs`):
//!
//! * **Observers never participate**: when disabled, every tap is one
//!   relaxed atomic load; when enabled, writers claim a ring slot with a
//!   `fetch_add` and a `try_lock` — they *never block* (a contended slot
//!   counts a drop instead), so the recorder cannot perturb scheduling.
//! * **Bounded memory**: each ring holds a fixed number of slots and
//!   overwrites the oldest entry; nothing grows with run length.
//! * **Bit-identicality**: labels and objective are bit-identical with the
//!   recorder enabled or disabled.

use std::borrow::Cow;
use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once, OnceLock, TryLockError};
use std::time::Instant;

use crate::util::json::{self, Json};
use crate::util::sync::lock_recover;

/// Span-completion ring capacity.
pub const SPAN_RING_CAP: usize = 256;
/// Warn/error log-record ring capacity.
pub const LOG_RING_CAP: usize = 128;
/// Metric-snapshot ring capacity.
pub const SNAPSHOT_RING_CAP: usize = 8;
/// Per-snapshot exposition cap (snapshots beyond it are truncated).
pub const SNAPSHOT_MAX_BYTES: usize = 16 * 1024;
/// Minimum microseconds between periodic metric snapshots.
pub const SNAPSHOT_PERIOD_US: u64 = 1_000_000;
/// Schema tag of the diagnostics document.
pub const DIAGNOSTICS_SCHEMA: &str = "bigmeans.diagnostics.v1";

/// Bounded multi-producer ring: a slot is claimed by `fetch_add` on the
/// head sequence and written under a `try_lock` — a writer that loses the
/// (rare) race for a wrapping slot drops its entry rather than block.
struct Ring<T: Clone> {
    slots: Vec<Mutex<Option<(u64, T)>>>,
    head: AtomicU64,
    dropped: AtomicU64,
}

impl<T: Clone> Ring<T> {
    fn new(cap: usize) -> Ring<T> {
        Ring {
            slots: (0..cap).map(|_| Mutex::new(None)).collect(),
            head: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    fn push(&self, value: T) {
        let seq = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(seq % self.slots.len() as u64) as usize];
        match slot.try_lock() {
            Ok(mut guard) => *guard = Some((seq, value)),
            Err(_) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Entries ever pushed (survivors are the newest `cap` of these).
    fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Non-destructive snapshot, oldest first. Readers use `try_lock`
    /// like the writers: a slot mid-write is skipped, never waited on, so
    /// the crash path cannot block on a lock the dying thread holds.
    fn collect_sorted(&self) -> Vec<T> {
        let mut entries: Vec<(u64, T)> = self
            .slots
            .iter()
            .filter_map(|slot| match slot.try_lock() {
                Ok(guard) => guard.clone(),
                Err(TryLockError::Poisoned(poisoned)) => poisoned.into_inner().clone(),
                Err(TryLockError::WouldBlock) => None,
            })
            .collect();
        entries.sort_by_key(|(seq, _)| *seq);
        entries.into_iter().map(|(_, v)| v).collect()
    }

    fn clear(&self) {
        for slot in &self.slots {
            *lock_recover(slot) = None;
        }
        self.head.store(0, Ordering::Relaxed);
        self.dropped.store(0, Ordering::Relaxed);
    }
}

#[derive(Clone)]
struct SpanRec {
    cat: &'static str,
    name: String,
    ts_us: u64,
    dur_us: u64,
}

#[derive(Clone)]
struct LogRec {
    ts: String,
    level: &'static str,
    target: String,
    message: String,
}

#[derive(Clone)]
struct SnapRec {
    at_us: u64,
    exposition: String,
}

thread_local! {
    /// Open spans on this thread, innermost last — what the panic hook
    /// reads to name the span that was live when the thread died.
    static SPAN_STACK: RefCell<Vec<(&'static str, Cow<'static, str>)>> =
        const { RefCell::new(Vec::new()) };
}

/// The process-wide flight recorder (see [`recorder`]).
pub struct Recorder {
    enabled: AtomicBool,
    epoch: Instant,
    spans: Ring<SpanRec>,
    logs: Ring<LogRec>,
    snapshots: Ring<SnapRec>,
    diag_path: Mutex<Option<PathBuf>>,
    crash_dumped: AtomicBool,
}

impl Recorder {
    fn new() -> Recorder {
        Recorder {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            spans: Ring::new(SPAN_RING_CAP),
            logs: Ring::new(LOG_RING_CAP),
            snapshots: Ring::new(SNAPSHOT_RING_CAP),
            diag_path: Mutex::new(None),
            crash_dumped: AtomicBool::new(false),
        }
    }

    /// Start recording, dumping to `path` on crash (panic or SIGTERM).
    pub fn enable(&self, path: &Path) {
        let _section = super::section::enter();
        *lock_recover(&self.diag_path) = Some(path.to_path_buf());
        self.enabled.store(true, Ordering::Relaxed);
        spawn_snapshot_thread();
    }

    /// Start recording with no crash-dump file (tests, serve-op-only use).
    pub fn enable_unsinked(&self) {
        self.enabled.store(true, Ordering::Relaxed);
        spawn_snapshot_thread();
    }

    /// Stop recording and clear every ring.
    pub fn disable_and_clear(&self) {
        let _section = super::section::enter();
        self.enabled.store(false, Ordering::Relaxed);
        *lock_recover(&self.diag_path) = None;
        self.spans.clear();
        self.logs.clear();
        self.snapshots.clear();
        self.crash_dumped.store(false, Ordering::Relaxed);
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// The configured crash-dump path, if any.
    pub fn diag_path(&self) -> Option<PathBuf> {
        let _section = super::section::enter();
        lock_recover(&self.diag_path).clone()
    }

    /// Crash-safe variant: never blocks. A contended path lock (the rare
    /// enable/disable race) forfeits the dump rather than hanging a dying
    /// process.
    fn diag_path_try(&self) -> Option<PathBuf> {
        match self.diag_path.try_lock() {
            Ok(guard) => guard.clone(),
            Err(TryLockError::Poisoned(poisoned)) => poisoned.into_inner().clone(),
            Err(TryLockError::WouldBlock) => None,
        }
    }

    /// Tap: one completed span (called by the tracer; pre-gated there).
    pub(crate) fn record_span(&self, cat: &'static str, name: &str, ts_us: u64, dur_us: u64) {
        self.spans.push(SpanRec { cat, name: name.to_string(), ts_us, dur_us });
    }

    /// Tap: one warn/error log record (called by `obs::log`; pre-gated).
    pub(crate) fn record_log(&self, ts: &str, level: &'static str, target: &str, message: &str) {
        self.logs.push(LogRec {
            ts: ts.to_string(),
            level,
            target: target.to_string(),
            message: message.to_string(),
        });
    }

    /// The full diagnostics document. Non-destructive: the rings keep
    /// recording, so a serve `dump-diagnostics` probe can be issued
    /// repeatedly. `crash` carries panic/signal context when dying.
    pub fn dump_json(&self, trigger: &str, crash: Option<Json>) -> Json {
        let registry = super::metrics();
        let mut snapshots: Vec<Json> = self
            .snapshots
            .collect_sorted()
            .into_iter()
            .map(|s| {
                json::obj(vec![
                    ("at_us", json::num(s.at_us as f64)),
                    ("exposition", json::s(&s.exposition)),
                ])
            })
            .collect();
        if registry.enabled() {
            // Final snapshot at dump time — the numbers at the moment of
            // death are the ones a post-mortem wants most.
            snapshots.push(json::obj(vec![
                ("at_us", json::num(self.epoch.elapsed().as_micros() as f64)),
                ("exposition", json::s(&truncate_utf8(registry.render(), SNAPSHOT_MAX_BYTES))),
            ]));
        }
        let spans: Vec<Json> = self
            .spans
            .collect_sorted()
            .into_iter()
            .map(|sp| {
                json::obj(vec![
                    ("cat", json::s(sp.cat)),
                    ("name", json::s(&sp.name)),
                    ("ts_us", json::num(sp.ts_us as f64)),
                    ("dur_us", json::num(sp.dur_us as f64)),
                ])
            })
            .collect();
        let logs: Vec<Json> = self
            .logs
            .collect_sorted()
            .into_iter()
            .map(|l| {
                json::obj(vec![
                    ("ts", json::s(&l.ts)),
                    ("level", json::s(l.level)),
                    ("target", json::s(&l.target)),
                    ("message", json::s(&l.message)),
                ])
            })
            .collect();
        json::obj(vec![
            ("schema", json::s(DIAGNOSTICS_SCHEMA)),
            ("written_at", json::s(&super::log::timestamp_utc())),
            ("trigger", json::s(trigger)),
            ("uptime_us", json::num(self.epoch.elapsed().as_micros() as f64)),
            ("crash", crash.unwrap_or(Json::Null)),
            ("spans", json::arr(spans)),
            ("spans_recorded", json::num(self.spans.recorded() as f64)),
            ("spans_dropped", json::num(self.spans.dropped() as f64)),
            ("logs", json::arr(logs)),
            ("logs_recorded", json::num(self.logs.recorded() as f64)),
            ("logs_dropped", json::num(self.logs.dropped() as f64)),
            ("metrics_snapshots", json::arr(snapshots)),
        ])
    }

    /// Write the diagnostics document to an explicit path.
    pub fn dump_to(&self, path: &Path, trigger: &str, crash: Option<Json>) -> Result<(), String> {
        let doc = self.dump_json(trigger, crash);
        std::fs::write(path, doc.to_string() + "\n")
            .map_err(|e| format!("write diagnostics {}: {e}", path.display()))
    }

    /// Crash-path dump to the configured path; only the *first* crash wins
    /// (a panicking worker and the unwinding main thread must not race the
    /// same file). Returns the path written, if any.
    fn dump_on_crash(&self, trigger: &str, crash: Option<Json>) -> Option<PathBuf> {
        if !self.enabled() || self.crash_dumped.swap(true, Ordering::SeqCst) {
            return None;
        }
        let path = self.diag_path_try()?;
        self.dump_to(&path, trigger, crash).ok()?;
        Some(path)
    }
}

/// Spawn (once) the detached snapshot thread: every [`SNAPSHOT_PERIOD_US`]
/// it captures the metric exposition into the snapshot ring. A dedicated
/// thread keeps registry serialisation (string formatting, allocation)
/// off the workers' span-completion path — the recorder is always on in
/// cluster runs, so the hot path must not pay for snapshots — and stamps
/// each snapshot with the *current* elapsed time rather than a span's
/// start timestamp. Idle cost while disabled: one wake per period.
fn spawn_snapshot_thread() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let _ = std::thread::Builder::new()
            .name("bigmeans-snapshot".into())
            .spawn(|| {
                // This thread can exist before install_crash_handlers sets
                // the process mask; it must never be SIGTERM's delivery
                // target or the watcher would lose the dump.
                #[cfg(unix)]
                sig::block_current_thread();
                loop {
                    std::thread::sleep(std::time::Duration::from_micros(SNAPSHOT_PERIOD_US));
                    let rec = recorder();
                    let registry = super::metrics();
                    if !rec.enabled() || !registry.enabled() {
                        continue;
                    }
                    rec.snapshots.push(SnapRec {
                        at_us: rec.epoch.elapsed().as_micros() as u64,
                        exposition: truncate_utf8(registry.render(), SNAPSHOT_MAX_BYTES),
                    });
                }
            });
    });
}

fn truncate_utf8(mut text: String, max: usize) -> String {
    if text.len() > max {
        let mut cut = max;
        while cut > 0 && !text.is_char_boundary(cut) {
            cut -= 1;
        }
        text.truncate(cut);
    }
    text
}

/// The process-wide flight recorder singleton. Disabled until
/// [`Recorder::enable`]; every tap is a relaxed-atomic no-op until then.
pub fn recorder() -> &'static Recorder {
    static RECORDER: OnceLock<Recorder> = OnceLock::new();
    RECORDER.get_or_init(Recorder::new)
}

/// Push an open span onto this thread's stack; returns whether it was
/// pushed (the recorder was enabled), so the guard knows to pop.
pub(crate) fn stack_push(cat: &'static str, name: Cow<'static, str>) -> bool {
    if !recorder().enabled() {
        return false;
    }
    SPAN_STACK.with(|stack| stack.borrow_mut().push((cat, name)));
    true
}

pub(crate) fn stack_pop() {
    SPAN_STACK.with(|stack| {
        stack.borrow_mut().pop();
    });
}

/// The current thread's open spans, outermost first (`cat` values).
pub fn current_span_stack() -> Vec<String> {
    SPAN_STACK.with(|stack| {
        stack
            .borrow()
            .iter()
            .map(|(cat, name)| {
                if cat == name {
                    (*cat).to_string()
                } else {
                    format!("{cat}:{name}")
                }
            })
            .collect()
    })
}

/// Install the crash handlers: a panic hook (chaining the previous one)
/// and, on unix, a SIGTERM watcher thread. Both flush the tracer — so a
/// `--trace` file is a complete, closed JSON document even when the run
/// dies — and dump the flight recorder to its configured diagnostics
/// path, naming the panicking span. Idempotent.
pub fn install_crash_handlers() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            prev(info);
            // A panic raised inside an obs lock section (tracer shards,
            // registry family map, recorder bookkeeping) still holds that
            // non-reentrant mutex on this thread; flushing here would
            // self-deadlock and hang the process instead of letting it
            // die. Degrade to the chained hook only.
            if super::section::active() {
                return;
            }
            let message = if let Some(s) = info.payload().downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = info.payload().downcast_ref::<String>() {
                s.clone()
            } else {
                "non-string panic payload".to_string()
            };
            let location = info
                .location()
                .map(|l| format!("{}:{}:{}", l.file(), l.line(), l.column()))
                .unwrap_or_else(|| "unknown".to_string());
            let stack = current_span_stack();
            let crash = json::obj(vec![
                ("kind", json::s("panic")),
                ("message", json::s(&message)),
                ("location", json::s(&location)),
                (
                    "thread",
                    json::s(std::thread::current().name().unwrap_or("unnamed")),
                ),
                (
                    "panicking_span",
                    stack.last().map(|s| json::s(s)).unwrap_or(Json::Null),
                ),
                ("span_stack", json::arr(stack.iter().map(|s| json::s(s)).collect())),
            ]);
            crash_dump("panic", Some(crash));
        }));
        #[cfg(unix)]
        sig::install();
    });
}

/// Shared crash path: flush the tracer (closing the trace JSON), then dump
/// the recorder. Called from the panic hook and the SIGTERM handler.
fn crash_dump(trigger: &str, crash: Option<Json>) {
    // The hook runs *before* unwinding, so buffered spans of the dying
    // thread are still in their shards — flush writes a valid document.
    let _ = super::tracer().flush();
    if let Some(path) = recorder().dump_on_crash(trigger, crash) {
        eprintln!("flight recorder: diagnostics dumped to {}", path.display());
    }
}

#[cfg(unix)]
mod sig {
    //! SIGTERM handling via a dedicated `sigwait` thread, not an async
    //! signal handler. The dump takes mutexes and allocates; doing that
    //! inside a handler that interrupted a thread holding one of those
    //! locks (or sitting inside malloc) deadlocks the process instead of
    //! terminating it. So the signal is blocked process-wide (threads
    //! spawned after install inherit the mask) and a watcher thread waits
    //! for it synchronously, dumps from ordinary thread context where
    //! locking is safe, then unblocks and re-raises so the exit status
    //! still says "killed by SIGTERM".

    use crate::util::json::{self, Json};
    use std::os::raw::c_int;

    const SIGTERM: c_int = 15;
    #[cfg(target_os = "linux")]
    const SIG_BLOCK: c_int = 0;
    #[cfg(target_os = "linux")]
    const SIG_UNBLOCK: c_int = 1;
    #[cfg(not(target_os = "linux"))]
    const SIG_BLOCK: c_int = 1;
    #[cfg(not(target_os = "linux"))]
    const SIG_UNBLOCK: c_int = 2;

    /// At least as large as any unix `sigset_t` (glibc 128 B, musl 8 B,
    /// macOS 4 B); `sigemptyset`/`sigaddset` fill in the real layout.
    #[repr(C)]
    struct SigSet([u64; 16]);

    extern "C" {
        fn sigemptyset(set: *mut SigSet) -> c_int;
        fn sigaddset(set: *mut SigSet, signum: c_int) -> c_int;
        fn pthread_sigmask(how: c_int, set: *const SigSet, old: *mut SigSet) -> c_int;
        fn sigwait(set: *const SigSet, sig: *mut c_int) -> c_int;
        fn raise(signum: c_int) -> c_int;
    }

    fn term_set() -> SigSet {
        let mut set = SigSet([0; 16]);
        unsafe {
            sigemptyset(&mut set);
            sigaddset(&mut set, SIGTERM);
        }
        set
    }

    /// Block SIGTERM on the calling thread — for obs threads that may be
    /// spawned before [`install`] sets the inheritable process mask.
    pub fn block_current_thread() {
        unsafe {
            pthread_sigmask(SIG_BLOCK, &term_set(), std::ptr::null_mut());
        }
    }

    pub fn install() {
        // Block SIGTERM on the installing thread. install runs before the
        // worker pools spawn, so every later thread inherits the mask and
        // kernel delivery has nowhere to land but the watcher's sigwait.
        unsafe {
            pthread_sigmask(SIG_BLOCK, &term_set(), std::ptr::null_mut());
        }
        let _ = std::thread::Builder::new()
            .name("bigmeans-sigterm".into())
            .spawn(|| {
                let set = term_set();
                let mut sig: c_int = 0;
                if unsafe { sigwait(&set, &mut sig) } != 0 {
                    return;
                }
                // SIGTERM is process-directed: no one thread's span stack
                // is "the" dying one, so the crash context leaves it
                // empty — the spans ring still names recent work.
                let crash = json::obj(vec![
                    ("kind", json::s("signal")),
                    ("signal", json::s("SIGTERM")),
                    ("panicking_span", Json::Null),
                    ("span_stack", json::arr(Vec::new())),
                ]);
                super::crash_dump("sigterm", Some(crash));
                unsafe {
                    // The default disposition was never replaced; unblock
                    // on this thread and re-raise to die with SIGTERM.
                    pthread_sigmask(SIG_UNBLOCK, &set, std::ptr::null_mut());
                    raise(SIGTERM);
                }
            });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_newest_and_stays_bounded() {
        let ring: Ring<u64> = Ring::new(8);
        for i in 0..100u64 {
            ring.push(i);
        }
        let got = ring.collect_sorted();
        assert_eq!(got.len(), 8);
        assert_eq!(got, (92..100).collect::<Vec<_>>());
        assert_eq!(ring.recorded(), 100);
        ring.clear();
        assert!(ring.collect_sorted().is_empty());
        assert_eq!(ring.recorded(), 0);
    }

    #[test]
    fn ring_survives_concurrent_writers() {
        let ring: Ring<u64> = Ring::new(16);
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let ring = &ring;
                scope.spawn(move || {
                    for i in 0..1000 {
                        ring.push(t * 10_000 + i);
                    }
                });
            }
        });
        let survivors = ring.collect_sorted();
        assert!(survivors.len() <= 16);
        assert_eq!(ring.recorded(), 4000);
        // Drops are possible (slot try_lock races) but bounded by writes.
        assert!(ring.dropped() <= 4000);
    }

    #[test]
    fn truncate_respects_char_boundaries() {
        assert_eq!(truncate_utf8("abcdef".into(), 4), "abcd");
        // 'é' is two bytes; cutting mid-char must back off.
        let s = "aé".to_string();
        assert_eq!(truncate_utf8(s, 2), "a");
    }

    #[test]
    fn span_stack_push_pop_tracks_depth() {
        // The recorder singleton may be enabled by other tests; drive the
        // stack helpers directly.
        SPAN_STACK.with(|s| s.borrow_mut().clear());
        SPAN_STACK.with(|s| s.borrow_mut().push(("shot", Cow::Borrowed("run_shot"))));
        SPAN_STACK.with(|s| s.borrow_mut().push(("shot.lloyd", Cow::Borrowed("lloyd"))));
        let stack = current_span_stack();
        assert_eq!(stack, vec!["shot:run_shot", "shot.lloyd:lloyd"]);
        stack_pop();
        stack_pop();
        assert!(current_span_stack().is_empty());
    }
}
