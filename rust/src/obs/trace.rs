//! Shot-lifecycle tracing: Chrome trace-event-format spans, ring-buffered
//! per thread and flushed once at exit.
//!
//! A [`Span`] guard records one `ph:"X"` complete event (category, name,
//! start, duration) when dropped. The enabled check is a single relaxed
//! atomic load, so a disabled tracer costs one branch per span site and
//! never calls `Instant::now()` — the hot path stays untouched unless the
//! user asked for a trace. Each thread buffers its events in a
//! lazily-registered shard behind its own mutex (uncontended except at
//! flush), capped at [`SHARD_CAP`] events; overflow increments a dropped
//! counter instead of growing without bound.
//!
//! [`Tracer::flush_to`] serialises every shard through
//! [`crate::util::json`] into the `{"traceEvents": [...]}` document that
//! Perfetto / `chrome://tracing` loads directly. Tracing is an observer:
//! it never branches the computation it watches, so the bit-identicality
//! contracts hold with tracing enabled (gated by `tests/property_obs.rs`).

use std::borrow::Cow;
use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::{self, Json};
use crate::util::sync::lock_recover;

/// Per-thread event cap; beyond it events are counted as dropped.
pub const SHARD_CAP: usize = 1 << 16;

struct Event {
    cat: &'static str,
    name: Cow<'static, str>,
    ts_us: u64,
    dur_us: u64,
}

struct Shard {
    tid: u64,
    events: Vec<Event>,
    dropped: u64,
}

struct TracerState {
    out_path: Option<PathBuf>,
    shards: Vec<Arc<Mutex<Shard>>>,
    next_tid: u64,
}

/// The process-wide tracer (see [`tracer`]).
pub struct Tracer {
    enabled: AtomicBool,
    epoch: Instant,
    state: Mutex<TracerState>,
}

thread_local! {
    static LOCAL_SHARD: RefCell<Option<Arc<Mutex<Shard>>>> = const { RefCell::new(None) };
}

impl Tracer {
    fn new() -> Tracer {
        Tracer {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            state: Mutex::new(TracerState {
                out_path: None,
                shards: Vec::new(),
                next_tid: 0,
            }),
        }
    }

    /// Start collecting spans; [`Tracer::flush`] will write them to
    /// `path` as a Chrome trace-event JSON document.
    pub fn enable(&self, path: &Path) {
        let _section = super::section::enter();
        lock_recover(&self.state).out_path = Some(path.to_path_buf());
        self.enabled.store(true, Ordering::Relaxed);
    }

    /// Collect spans without a file sink (bench A/B rows); flush drops
    /// the events.
    pub fn enable_unsinked(&self) {
        let _section = super::section::enter();
        lock_recover(&self.state).out_path = None;
        self.enabled.store(true, Ordering::Relaxed);
    }

    /// Stop collecting and discard everything buffered so far.
    pub fn disable_and_clear(&self) {
        let _section = super::section::enter();
        self.enabled.store(false, Ordering::Relaxed);
        let mut st = lock_recover(&self.state);
        st.out_path = None;
        for shard in &st.shards {
            let mut sh = lock_recover(shard);
            sh.events.clear();
            sh.dropped = 0;
        }
    }

    /// Whether spans should be captured: the tracer proper is on, or the
    /// flight recorder wants span completions. Two relaxed loads when
    /// everything is off.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed) || super::recorder::recorder().enabled()
    }

    /// Open a span with a static name. One relaxed load when disabled.
    #[inline]
    pub fn span(&'static self, cat: &'static str, name: &'static str) -> Span {
        if !self.enabled() {
            return Span { live: None, stacked: false };
        }
        let stacked = super::recorder::stack_push(cat, Cow::Borrowed(name));
        Span { live: Some((self, cat, Cow::Borrowed(name), Instant::now())), stacked }
    }

    /// Open a span with a runtime name (e.g. a tuner arm label).
    #[inline]
    pub fn span_dyn(&'static self, cat: &'static str, name: String) -> Span {
        if !self.enabled() {
            return Span { live: None, stacked: false };
        }
        let stacked = super::recorder::stack_push(cat, Cow::Owned(name.clone()));
        Span { live: Some((self, cat, Cow::Owned(name), Instant::now())), stacked }
    }

    fn record(&self, cat: &'static str, name: Cow<'static, str>, start: Instant) {
        let _section = super::section::enter();
        let ts_us = start.duration_since(self.epoch).as_micros() as u64;
        let dur_us = start.elapsed().as_micros() as u64;
        let recorder = super::recorder::recorder();
        if recorder.enabled() {
            recorder.record_span(cat, &name, ts_us, dur_us);
        }
        // Shards buffer only for the tracer proper — a recorder-only run
        // must not grow trace memory it will never flush.
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        LOCAL_SHARD.with(|slot| {
            let mut slot = slot.borrow_mut();
            if slot.is_none() {
                let mut st = lock_recover(&self.state);
                st.next_tid += 1;
                let shard = Arc::new(Mutex::new(Shard {
                    tid: st.next_tid,
                    events: Vec::new(),
                    dropped: 0,
                }));
                st.shards.push(Arc::clone(&shard));
                *slot = Some(shard);
            }
            let shard = slot.as_ref().expect("shard just installed");
            let mut sh = lock_recover(shard);
            if sh.events.len() >= SHARD_CAP {
                sh.dropped += 1;
            } else {
                sh.events.push(Event { cat, name, ts_us, dur_us });
            }
        });
    }

    /// Events currently buffered across all shards (telemetry/tests).
    pub fn buffered(&self) -> (usize, u64) {
        let _section = super::section::enter();
        let st = lock_recover(&self.state);
        let mut events = 0;
        let mut dropped = 0;
        for shard in &st.shards {
            let sh = lock_recover(shard);
            events += sh.events.len();
            dropped += sh.dropped;
        }
        (events, dropped)
    }

    /// Serialise every buffered span to the Chrome trace-event JSON
    /// document, draining the shards.
    pub fn render(&self) -> Json {
        let _section = super::section::enter();
        let st = lock_recover(&self.state);
        let mut events = Vec::new();
        let mut dropped = 0u64;
        for shard in &st.shards {
            let mut sh = lock_recover(shard);
            dropped += sh.dropped;
            sh.dropped = 0;
            let tid = sh.tid;
            for ev in sh.events.drain(..) {
                events.push(json::obj(vec![
                    ("ph", json::s("X")),
                    ("cat", json::s(ev.cat)),
                    ("name", json::s(&ev.name)),
                    ("ts", json::num(ev.ts_us as f64)),
                    ("dur", json::num(ev.dur_us as f64)),
                    ("pid", json::num(1.0)),
                    ("tid", json::num(tid as f64)),
                ]));
            }
        }
        json::obj(vec![
            ("traceEvents", json::arr(events)),
            ("displayTimeUnit", json::s("ms")),
            ("droppedEvents", json::num(dropped as f64)),
        ])
    }

    /// Write the buffered trace to the path given at [`Tracer::enable`]
    /// time (no-op when tracing is off or unsinked). Returns the path
    /// written, so callers can log it.
    pub fn flush(&self) -> Result<Option<PathBuf>, String> {
        if !self.enabled() {
            return Ok(None);
        }
        let path = {
            let _section = super::section::enter();
            lock_recover(&self.state).out_path.clone()
        };
        match path {
            None => {
                self.render(); // drain the shards
                Ok(None)
            }
            Some(path) => {
                self.flush_to(&path)?;
                Ok(Some(path))
            }
        }
    }

    /// Write the buffered trace to an explicit path.
    pub fn flush_to(&self, path: &Path) -> Result<(), String> {
        let doc = self.render();
        std::fs::write(path, doc.to_string() + "\n")
            .map_err(|e| format!("write trace {}: {e}", path.display()))
    }
}

/// RAII span guard: drop records the event (and pops this thread's
/// flight-recorder span stack when the open pushed onto it).
pub struct Span {
    live: Option<(&'static Tracer, &'static str, Cow<'static, str>, Instant)>,
    stacked: bool,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((tracer, cat, name, start)) = self.live.take() {
            tracer.record(cat, name, start);
        }
        if self.stacked {
            super::recorder::stack_pop();
        }
    }
}

/// The process-wide tracer singleton.
pub fn tracer() -> &'static Tracer {
    static TRACER: OnceLock<Tracer> = OnceLock::new();
    TRACER.get_or_init(Tracer::new)
}
