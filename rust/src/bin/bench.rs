//! `bench` — engine, tuner, storage, and serving benchmarks, no external
//! deps.
//!
//! Five suites (`--suite assign|tuner|io|final|serve|all`, default
//! `assign`):
//!
//! * **assign** — times the fused panel engine, the bounded
//!   (Hamerly-pruned) engine, the Elkan engine, the rescan-adaptive
//!   hybrid engine, and the pre-fusion two-pass reference kernel on a
//!   synthetic workload (default 1M×16, k=64) — once on uniform data
//!   (worst case for pruning) and once on separated Gaussian blobs (best
//!   case) — plus per-ISA A/B rows (the panel engine forced onto the
//!   scalar backend vs the detected-best SIMD dispatch, and onto avx512
//!   on hosts that detect it; the avx512 rows are skipped, not failed,
//!   elsewhere), then emits `BENCH_assign.json` with wall times and
//!   distance-eval counts.
//! * **tuner** — races the competitive portfolio tuner against every
//!   fixed-sample-size baseline from the same grid at an equal shot
//!   budget (default 1M×16 uniform + blob workloads) and emits
//!   `BENCH_tuner.json`: tuned vs best-fixed vs worst-fixed final
//!   objective.
//! * **io** — the `.bmx` v3 block store: ingest MB/s and on-disk ratio
//!   for every dtype × codec combination, plus cold vs cached
//!   random-chunk sampling latency per codec (f32), emitting
//!   `BENCH_io.json`.
//! * **final** — the hierarchical-pruned final pass: the same blocked
//!   blob workload clustered through a block store with min/max
//!   summaries (pruned + double-buffered) vs. one without (unpruned
//!   baseline) vs. in-memory, plus a decode-free f16 A/B (fused raw-f16
//!   widening vs. the decoded-f32 cache path, bit-identical to each
//!   other) and a conditional avx512 row, emitting `BENCH_final.json`
//!   (final-pass wall times, blocks skipped, decode-only scan time, and
//!   bit-identical objective cross-checks).
//! * **serve** — the clustering daemon: boots a server on an ephemeral
//!   loopback port, fires batched assign queries from concurrent client
//!   workers while an in-process publish hot-swaps the model mid-run,
//!   checks every response bit-identical to the offline `assign_only`
//!   labels for whichever generation answered, and emits
//!   `BENCH_serve.json` (QPS, rows/s, client-side p50/p95/p99).
//!
//! CI runs scaled-down versions of all five as non-gating smoke steps,
//! plus one *gating* regression check: `--compare BASELINE.json
//! [--tolerance PCT]` diffs the suite's output document against a
//! committed baseline after the run and exits nonzero when a perf leaf
//! (wall time, throughput, speedup, overhead ratio) regressed beyond the
//! tolerance (see `bench_harness::compare`).
//!
//! ```text
//! cargo run --release --bin bench -- [--suite assign|tuner|io|final|serve|all]
//!     [--m N] [--n N] [--k N] [--iters N] [--shots N] [--s N] [--out PATH]
//!     [--tuner-out PATH] [--io-m N] [--io-s N] [--io-samples N] [--block-rows N]
//!     [--io-out PATH] [--final-m N] [--final-out PATH] [--serve-batch N]
//!     [--serve-workers N] [--serve-requests N] [--serve-out PATH]
//!     [--compare BASELINE.json] [--tolerance PCT]
//! ```

use std::time::Instant;

use bigmeans::coordinator::config::{ParallelMode, StopCondition};
use bigmeans::data::dataset::Dataset;
use bigmeans::data::source::DataSource;
use bigmeans::kernels::assign::{AssignOut, BLOCK_ROWS};
use bigmeans::kernels::distance::{sq_dist_panel, sq_norm};
use bigmeans::kernels::engine::{
    BoundedEngine, ElkanEngine, HybridEngine, KernelEngine, LloydState, PanelEngine,
};
use bigmeans::kernels::update_centroids;
use bigmeans::kernels::{active_isa, detect_isa, set_isa, DistanceIsa};
use bigmeans::metrics::Counters;
use bigmeans::obs;
use bigmeans::store::{copy_to_store, BlockStore, Codec, Dtype, StoreOptions};
use bigmeans::tuner::{self, ArmSpec, TunerConfig};
use bigmeans::util::cli::Args;
use bigmeans::util::json::{arr, num, obj, s, Json};
use bigmeans::util::rng::Rng;
use bigmeans::{BigMeans, BigMeansConfig};

/// The seed (pre-fusion) assignment kernel: dense distance panel into a
/// `rows×k` buffer, argmin in a second pass. Kept verbatim as the baseline
/// the fused path is measured against.
fn reference_assign(
    points: &[f32],
    centroids: &[f32],
    m: usize,
    n: usize,
    k: usize,
    counters: &mut Counters,
) -> AssignOut {
    let mut labels = vec![0u32; m];
    let mut mins = vec![0f32; m];
    let mut sums = vec![0f64; k * n];
    let mut counts = vec![0u64; k];
    let mut objective = 0f64;
    let c_sq: Vec<f32> = (0..k).map(|j| sq_norm(&centroids[j * n..(j + 1) * n])).collect();
    let mut panel = vec![0f32; BLOCK_ROWS * k];
    let mut x_sq = vec![0f32; BLOCK_ROWS];
    let mut row = 0;
    while row < m {
        let rows = BLOCK_ROWS.min(m - row);
        let block = &points[row * n..(row + rows) * n];
        for (i, xs) in x_sq.iter_mut().take(rows).enumerate() {
            *xs = sq_norm(&block[i * n..(i + 1) * n]);
        }
        sq_dist_panel(block, &x_sq[..rows], centroids, &c_sq, rows, k, n, &mut panel[..rows * k]);
        for i in 0..rows {
            let drow = &panel[i * k..(i + 1) * k];
            let mut best = 0usize;
            let mut best_d = drow[0];
            for (j, &d) in drow.iter().enumerate().skip(1) {
                if d < best_d {
                    best_d = d;
                    best = j;
                }
            }
            let g = row + i;
            labels[g] = best as u32;
            mins[g] = best_d;
            objective += best_d as f64;
            counts[best] += 1;
            let srow = &mut sums[best * n..(best + 1) * n];
            for (sv, xv) in srow.iter_mut().zip(&block[i * n..(i + 1) * n]) {
                *sv += *xv as f64;
            }
        }
        row += rows;
    }
    counters.add_distance_evals((m * k) as u64);
    AssignOut { labels, mins, sums, counts, objective }
}

struct Case {
    name: String,
    secs: f64,
    counters: Counters,
    objective: f64,
}

/// Fixed-iteration Lloyd loop through a [`KernelEngine`].
fn time_engine(
    name: &str,
    engine: &dyn KernelEngine,
    pts: &[f32],
    m: usize,
    n: usize,
    k: usize,
    iters: usize,
) -> Case {
    let mut c = pts[..k * n].to_vec();
    let mut old = vec![0f32; k * n];
    let mut state = LloydState::new(m);
    let mut counters = Counters::new();
    let mut objective = 0f64;
    let t0 = Instant::now();
    for _ in 0..iters {
        let _span = obs::tracer().span("bench.iter", "assign_step");
        let out = engine.assign_step(pts, &c, m, n, k, &mut state, &mut counters);
        objective = out.objective;
        old.copy_from_slice(&c);
        update_centroids(&out.sums, &out.counts, &mut c, k, n);
        state.apply_update(&old, &c, k, n);
    }
    Case { name: name.to_string(), secs: t0.elapsed().as_secs_f64(), counters, objective }
}

/// The same loop over the reference two-pass kernel.
fn time_reference(name: &str, pts: &[f32], m: usize, n: usize, k: usize, iters: usize) -> Case {
    let mut c = pts[..k * n].to_vec();
    let mut counters = Counters::new();
    let mut objective = 0f64;
    let t0 = Instant::now();
    for _ in 0..iters {
        let out = reference_assign(pts, &c, m, n, k, &mut counters);
        objective = out.objective;
        update_centroids(&out.sums, &out.counts, &mut c, k, n);
    }
    Case { name: name.to_string(), secs: t0.elapsed().as_secs_f64(), counters, objective }
}

fn uniform_data(rng: &mut Rng, m: usize, n: usize) -> Vec<f32> {
    (0..m * n).map(|_| rng.f32() * 100.0).collect()
}

/// `k` well-separated Gaussian blobs — the regime the paper targets and
/// where triangle-inequality pruning pays off.
fn blob_data(rng: &mut Rng, m: usize, n: usize, k: usize) -> Vec<f32> {
    let centers: Vec<f32> = (0..k * n).map(|_| rng.f32() * 100.0 - 50.0).collect();
    let mut pts = Vec::with_capacity(m * n);
    for i in 0..m {
        let c = &centers[(i % k) * n..(i % k + 1) * n];
        for &cv in c {
            pts.push(cv + 0.5 * rng.gaussian() as f32);
        }
    }
    pts
}

fn case_json(c: &Case) -> Json {
    obj(vec![
        ("name", s(&c.name)),
        ("secs", num(c.secs)),
        ("distance_evals", num(c.counters.distance_evals as f64)),
        ("pruned_evals", num(c.counters.pruned_evals as f64)),
        ("pruned_blocks", num(c.counters.pruned_blocks as f64)),
        ("hybrid_switches", num(c.counters.hybrid_switches as f64)),
        ("objective", num(c.objective)),
    ])
}

/// The tuner-vs-fixed-baselines suite: every fixed sample size from the
/// grid gets the same shot budget the tuned run gets, on the same data and
/// seed — so "tuned ≤ best fixed" is an apples-to-apples comparison.
fn tuner_suite(args: &Args) -> Result<(), String> {
    let m = args.usize("m", 1_000_000)?;
    let n = args.usize("n", 16)?;
    let k = args.usize("k", 25)?;
    let base_s = args.usize("s", 4096)?;
    let shots = args.u64("shots", 40)?;
    let out_path = args.get_or("tuner-out", "BENCH_tuner.json").to_string();
    if k == 0 || k > m {
        return Err(format!("k={k} out of range for m={m}"));
    }
    let multipliers = [0.25f64, 0.5, 1.0, 2.0, 4.0];
    let mut rng = Rng::new(0x7E57);
    eprintln!("generating {m}×{n} uniform + blob datasets (k={k}, shots={shots}) …");
    let workloads = [
        ("uniform", Dataset::from_vec("uniform", uniform_data(&mut rng, m, n), m, n)),
        ("blobs", Dataset::from_vec("blobs", blob_data(&mut rng, m, n, k), m, n)),
    ];
    let base_cfg = |chunk: usize| {
        BigMeansConfig::new(k, chunk)
            .with_stop(StopCondition::MaxChunks(shots))
            .with_parallel(ParallelMode::ChunkParallel)
            .with_seed(42)
    };
    let mut workload_docs = Vec::new();
    for (wname, data) in &workloads {
        let mut fixed_docs = Vec::new();
        let mut best_fixed = f64::INFINITY;
        let mut worst_fixed = f64::NEG_INFINITY;
        for &mult in &multipliers {
            let chunk = ((base_s as f64 * mult).round() as usize).clamp(k, m);
            let t0 = Instant::now();
            let r = BigMeans::new(base_cfg(chunk)).run(data)?;
            let secs = t0.elapsed().as_secs_f64();
            eprintln!(
                "{wname:<8} fixed {mult:>5}x (s={chunk:<8}) {secs:>8.3}s  objective {:.6e}",
                r.objective
            );
            best_fixed = best_fixed.min(r.objective);
            worst_fixed = worst_fixed.max(r.objective);
            fixed_docs.push(obj(vec![
                ("multiplier", num(mult)),
                ("chunk_rows", num(chunk as f64)),
                ("objective", num(r.objective)),
                ("secs", num(secs)),
            ]));
        }
        let tcfg = TunerConfig::default()
            .with_arms(multipliers.iter().map(|&x| ArmSpec::new(x)).collect());
        let t0 = Instant::now();
        let race = tuner::run_race(&base_cfg(base_s), &tcfg, data)?;
        let secs = t0.elapsed().as_secs_f64();
        eprintln!(
            "{wname:<8} tuned ({})        {secs:>8.3}s  objective {:.6e}  (chose s={})",
            race.trace.controller, race.result.objective, race.chosen_chunk_rows
        );
        workload_docs.push(obj(vec![
            ("workload", s(wname)),
            ("tuned_objective", num(race.result.objective)),
            ("tuned_secs", num(secs)),
            ("tuned_validation_objective", num(race.validation_objective)),
            ("chosen_chunk_rows", num(race.chosen_chunk_rows as f64)),
            ("tuner", race.trace.to_json()),
            ("fixed", arr(fixed_docs)),
            ("best_fixed_objective", num(best_fixed)),
            ("worst_fixed_objective", num(worst_fixed)),
            // Same 1e-6 relative slack as the gating integration test:
            // runs converging to the same partition differ in the last
            // bits of the f32-accumulated means.
            (
                "tuned_beats_best_fixed",
                Json::Bool(race.result.objective <= best_fixed * (1.0 + 1e-6)),
            ),
        ]));
    }
    let doc = obj(vec![
        ("m", num(m as f64)),
        ("n", num(n as f64)),
        ("k", num(k as f64)),
        ("base_chunk", num(base_s as f64)),
        ("shots", num(shots as f64)),
        ("workloads", arr(workload_docs)),
    ]);
    std::fs::write(&out_path, doc.to_string() + "\n")
        .map_err(|e| format!("write {out_path}: {e}"))?;
    eprintln!("wrote {out_path}");
    Ok(())
}

/// The block-store IO suite: ingest throughput per dtype × codec, then
/// cold-vs-cached random-chunk sampling latency per codec (f32 stores,
/// identical chunk draws for every codec so latencies are comparable).
fn io_suite(args: &Args) -> Result<(), String> {
    let m = args.usize("io-m", 200_000)?;
    let n = args.usize("n", 16)?;
    let chunk_rows = args.usize("io-s", 4096)?.min(m);
    let samples = args.usize("io-samples", 32)?;
    let block_rows = args.usize("block-rows", 4096)?;
    let out_path = args.get_or("io-out", "BENCH_io.json").to_string();
    let mut rng = Rng::new(0x10_BE);
    eprintln!("generating {m}×{n} uniform dataset …");
    let data = Dataset::from_vec("io", uniform_data(&mut rng, m, n), m, n);
    let raw_bytes = (m * n * 4) as f64;
    let raw_mib = raw_bytes / (1 << 20) as f64;
    let dir = std::env::temp_dir().join(format!("bigmeans_bench_io_{}", std::process::id()));
    std::fs::create_dir_all(&dir).map_err(|e| format!("create {}: {e}", dir.display()))?;

    let combos = [
        (Dtype::F32, Codec::None),
        (Dtype::F32, Codec::Shuffle),
        (Dtype::F32, Codec::Lz),
        (Dtype::F64, Codec::None),
        (Dtype::F64, Codec::Lz),
        (Dtype::F16, Codec::None),
        (Dtype::F16, Codec::Lz),
    ];
    let mut ingest_docs = Vec::new();
    for (dtype, codec) in combos {
        let path = dir.join(format!("io_{}_{}.bmx", dtype.name(), codec.name()));
        let opts = StoreOptions { block_rows, dtype, codec, ..StoreOptions::default() };
        let t0 = Instant::now();
        copy_to_store(&data, &path, opts).map_err(|e| e.to_string())?;
        let secs = t0.elapsed().as_secs_f64();
        let file_bytes = std::fs::metadata(&path).map(|md| md.len()).unwrap_or(0);
        let mb_per_s = raw_mib / secs.max(1e-9);
        eprintln!(
            "ingest {:>4}/{:<7} {secs:>7.3}s  {mb_per_s:>8.1} MiB/s  \
             on-disk ratio {:.3}",
            dtype.name(),
            codec.name(),
            file_bytes as f64 / raw_bytes
        );
        ingest_docs.push(obj(vec![
            ("dtype", s(dtype.name())),
            ("codec", s(codec.name())),
            ("secs", num(secs)),
            ("mb_per_s", num(mb_per_s)),
            ("file_bytes", num(file_bytes as f64)),
            ("ratio_vs_raw_f32", num(file_bytes as f64 / raw_bytes)),
        ]));
    }

    // Identical chunk draws for every codec: cold = fresh open (every
    // touched block pays read + CRC + decode), warm = same draws again
    // (decoded-block LRU hits).
    let mut draw_rng = Rng::new(0x5A17);
    let chunks: Vec<Vec<usize>> = (0..samples)
        .map(|_| {
            let mut idx = draw_rng.sample_indices(m, chunk_rows);
            idx.sort_unstable();
            idx
        })
        .collect();
    let mut sample_docs = Vec::new();
    for codec in [Codec::None, Codec::Shuffle, Codec::Lz] {
        let path = dir.join(format!("io_f32_{}.bmx", codec.name()));
        let store = BlockStore::open(&path).map_err(|e| e.to_string())?;
        let mut out = vec![0f32; chunk_rows * n];
        let t0 = Instant::now();
        for idx in &chunks {
            store.sample_rows(idx, &mut out);
        }
        let cold = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        for idx in &chunks {
            store.sample_rows(idx, &mut out);
        }
        let warm = t1.elapsed().as_secs_f64();
        let (hits, misses) = store.cache_stats();
        eprintln!(
            "sample f32/{:<7} cold {cold:>7.4}s  warm {warm:>7.4}s  ({:.2}× speedup, \
             {hits} hits / {misses} misses)",
            codec.name(),
            cold / warm.max(1e-9)
        );
        sample_docs.push(obj(vec![
            ("codec", s(codec.name())),
            ("chunks", num(samples as f64)),
            ("chunk_rows", num(chunk_rows as f64)),
            ("cold_secs", num(cold)),
            ("warm_secs", num(warm)),
            ("warm_speedup", num(cold / warm.max(1e-9))),
            ("cache_hits", num(hits as f64)),
            ("cache_misses", num(misses as f64)),
        ]));
    }
    let _ = std::fs::remove_dir_all(&dir);

    let doc = obj(vec![
        ("m", num(m as f64)),
        ("n", num(n as f64)),
        ("block_rows", num(block_rows as f64)),
        ("raw_mib", num(raw_mib)),
        ("ingest", arr(ingest_docs)),
        ("sampling", arr(sample_docs)),
    ]);
    std::fs::write(&out_path, doc.to_string() + "\n")
        .map_err(|e| format!("write {out_path}: {e}"))?;
    eprintln!("wrote {out_path}");
    Ok(())
}

/// Grouped (block-aligned) separated blobs: cluster `i · k / m` owns row
/// `i`, so fixed-size store blocks are pure single-cluster boxes — the
/// workload where block-level pruning should fire on (nearly) every
/// block.
fn grouped_blob_data(rng: &mut Rng, m: usize, n: usize, k: usize) -> Vec<f32> {
    let centers: Vec<f32> = (0..k * n).map(|_| rng.f32() * 200.0 - 100.0).collect();
    let per = m.div_ceil(k);
    let mut pts = Vec::with_capacity(m * n);
    for i in 0..m {
        let c = (i / per).min(k - 1);
        for &cv in &centers[c * n..(c + 1) * n] {
            pts.push(cv + 0.3 * rng.gaussian() as f32);
        }
    }
    pts
}

/// The hierarchical-pruned final pass suite: same data, same seed, three
/// storage configurations — block store with summaries (pruned +
/// double-buffered), block store without (unpruned baseline), and
/// in-memory — compared on final-pass wall time with a bit-identical
/// objective cross-check, plus a decode-only full scan for context.
fn final_suite(args: &Args) -> Result<(), String> {
    let m = args.usize("final-m", 400_000)?;
    let n = args.usize("n", 16)?;
    let k = args.usize("k", 16)?.max(2);
    let block_rows = args.usize("block-rows", 4096)?;
    let shots = args.u64("shots", 10)?;
    let out_path = args.get_or("final-out", "BENCH_final.json").to_string();
    let mut rng = Rng::new(0xF17A);
    eprintln!("generating {m}×{n} grouped blob dataset (k={k}) …");
    let data = Dataset::from_vec("final", grouped_blob_data(&mut rng, m, n, k), m, n);
    let dir = std::env::temp_dir().join(format!("bigmeans_bench_final_{}", std::process::id()));
    std::fs::create_dir_all(&dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
    let codec = Codec::parse(args.get_or("codec", "lz")).ok_or("bad --codec")?;
    let base = StoreOptions { block_rows, codec, ..StoreOptions::default() };
    let pruned_path = dir.join("final_summaries.bmx");
    let plain_path = dir.join("final_plain.bmx");
    copy_to_store(&data, &pruned_path, base).map_err(|e| e.to_string())?;
    copy_to_store(&data, &plain_path, StoreOptions { summaries: false, ..base })
        .map_err(|e| e.to_string())?;

    let cfg = BigMeansConfig::new(k, 4096.min(m))
        .with_stop(StopCondition::MaxChunks(shots))
        .with_seed(42);
    let run = |src: &dyn DataSource| -> Result<(bigmeans::BigMeansResult, f64), String> {
        let t0 = Instant::now();
        let r = BigMeans::new(cfg.clone()).run(src)?;
        Ok((r, t0.elapsed().as_secs_f64()))
    };
    let pruned_store = BlockStore::open(&pruned_path).map_err(|e| e.to_string())?;
    let plain_store = BlockStore::open(&plain_path).map_err(|e| e.to_string())?;
    let blocks = pruned_store.blocks();
    let (r_pruned, _) = run(&pruned_store)?;
    let (r_plain, _) = run(&plain_store)?;
    let (r_mem, _) = run(&data)?;
    // Per-ISA A/B: the in-memory run forced onto the scalar distance
    // backend — bit-identical by the dispatch contract, slower at most.
    set_isa(DistanceIsa::Scalar).expect("scalar is always available");
    let (r_mem_scalar, _) = run(&data)?;
    set_isa(detect_isa()).expect("detected isa must be available");
    // AVX-512 A/B: skipped (not failed) on hosts without it, so the row
    // must never land in a committed baseline.
    let r_mem_avx512 = if DistanceIsa::Avx512.available() {
        set_isa(DistanceIsa::Avx512).expect("avx512 detected as available");
        let (r, _) = run(&data)?;
        set_isa(detect_isa()).expect("detected isa must be available");
        Some(r)
    } else {
        eprintln!("mem_final_secs_avx512: skipped (avx512 not detected)");
        None
    };
    // Decode-only full scan (fresh store so the cache is cold): the decode
    // bandwidth the double buffer hides behind the assignment shards.
    let scan_store = BlockStore::open(&plain_path).map_err(|e| e.to_string())?;
    let mut slab = vec![0f32; 8192.min(m) * n];
    let t0 = Instant::now();
    let mut start = 0usize;
    while start < m {
        let rows = 8192.min(m - start);
        scan_store.read_rows(start, &mut slab[..rows * n]);
        start += rows;
    }
    let decode_secs = t0.elapsed().as_secs_f64();

    let identical = r_pruned.objective.to_bits() == r_plain.objective.to_bits()
        && r_pruned.objective.to_bits() == r_mem.objective.to_bits()
        && r_pruned.objective.to_bits() == r_mem_scalar.objective.to_bits()
        && r_pruned.assignment == r_plain.assignment
        && r_pruned.assignment == r_mem.assignment
        && r_pruned.assignment == r_mem_scalar.assignment
        && r_mem_avx512.iter().all(|r| {
            r.objective.to_bits() == r_pruned.objective.to_bits()
                && r.assignment == r_pruned.assignment
        });
    let speedup = r_plain.cpu_full_secs / r_pruned.cpu_full_secs.max(1e-9);
    eprintln!(
        "final pass: pruned {:.3}s vs unpruned {:.3}s ({speedup:.2}×), mem {:.3}s | \
         {} of {blocks} blocks skipped | decode-only scan {decode_secs:.3}s | \
         bit-identical: {identical}",
        r_pruned.cpu_full_secs,
        r_plain.cpu_full_secs,
        r_mem.cpu_full_secs,
        r_pruned.counters.pruned_blocks,
    );
    if !identical {
        return Err("final suite: pruned pass diverged from the unpruned baseline".into());
    }

    // Decode-free f16 A/B: the same workload through an f16/raw store,
    // once on the fused path (raw blocks widened on the fly, decoded-f32
    // cache bypassed) and once forced through the decode path. The two
    // must be bit-identical to each other; their objective legitimately
    // differs from the f32 runs (the data was quantised on ingest), so
    // the cross-check is fused-vs-decoded only.
    let f16_path = dir.join("final_f16.bmx");
    copy_to_store(
        &data,
        &f16_path,
        StoreOptions { dtype: Dtype::F16, codec: Codec::None, ..base },
    )
    .map_err(|e| e.to_string())?;
    let f16_fused = BlockStore::open(&f16_path).map_err(|e| e.to_string())?;
    let fused_active = f16_fused.fused_f16_active();
    let (r_f16_fused, _) = run(&f16_fused)?;
    let f16_decoded = BlockStore::open(&f16_path).map_err(|e| e.to_string())?;
    f16_decoded.set_fused_f16(false);
    let (r_f16_decoded, _) = run(&f16_decoded)?;
    let f16_identical = r_f16_fused.objective.to_bits() == r_f16_decoded.objective.to_bits()
        && r_f16_fused.assignment == r_f16_decoded.assignment;
    let f16_speedup = r_f16_decoded.cpu_full_secs / r_f16_fused.cpu_full_secs.max(1e-9);
    eprintln!(
        "f16 final pass: fused {:.3}s vs decoded {:.3}s ({f16_speedup:.2}×, fused path \
         {}) | bit-identical: {f16_identical}",
        r_f16_fused.cpu_full_secs,
        r_f16_decoded.cpu_full_secs,
        if fused_active { "active" } else { "inactive" },
    );
    if !f16_identical {
        return Err("final suite: decode-free f16 pass diverged from the decode path".into());
    }
    let _ = std::fs::remove_dir_all(&dir);

    let mut entries = vec![
        ("m", num(m as f64)),
        ("n", num(n as f64)),
        ("k", num(k as f64)),
        ("block_rows", num(block_rows as f64)),
        ("codec", s(codec.name())),
        ("blocks", num(blocks as f64)),
        ("pruned_blocks", num(r_pruned.counters.pruned_blocks as f64)),
        ("isa", s(active_isa().name())),
        ("pruned_final_secs", num(r_pruned.cpu_full_secs)),
        ("unpruned_final_secs", num(r_plain.cpu_full_secs)),
        ("mem_final_secs", num(r_mem.cpu_full_secs)),
        ("mem_final_secs_scalar", num(r_mem_scalar.cpu_full_secs)),
        ("final_speedup", num(speedup)),
        ("decode_scan_secs", num(decode_secs)),
        ("pruned_evals", num(r_pruned.counters.pruned_evals as f64)),
        ("hybrid_switches", num(r_pruned.counters.hybrid_switches as f64)),
        ("distance_evals_pruned", num(r_pruned.counters.distance_evals as f64)),
        ("distance_evals_unpruned", num(r_plain.counters.distance_evals as f64)),
        ("objective", num(r_pruned.objective)),
        ("bit_identical", Json::Bool(identical)),
        ("f16_fused_active", Json::Bool(fused_active)),
        ("f16_fused_final_secs", num(r_f16_fused.cpu_full_secs)),
        ("f16_decoded_final_secs", num(r_f16_decoded.cpu_full_secs)),
        ("f16_fused_speedup", num(f16_speedup)),
        ("f16_bit_identical", Json::Bool(f16_identical)),
    ];
    // Conditional row: present only on hosts that detected avx512, so it
    // must stay out of committed baselines.
    if let Some(r) = &r_mem_avx512 {
        entries.push(("mem_final_secs_avx512", num(r.cpu_full_secs)));
    }
    let doc = obj(entries);
    std::fs::write(&out_path, doc.to_string() + "\n")
        .map_err(|e| format!("write {out_path}: {e}"))?;
    eprintln!("wrote {out_path}");
    Ok(())
}

/// The serve suite: concurrent batched queries against a live daemon with
/// a mid-run hot-swap, gated on bit-identity against the offline kernel.
fn serve_suite(args: &Args) -> Result<(), String> {
    use bigmeans::serve::{Client, ModelArtifact, ModelRegistry, ServeOptions, Server};
    use std::sync::Arc;

    let k = args.usize("k", 64)?.max(1);
    let n = args.usize("n", 16)?.max(1);
    let batch_rows = args.usize("serve-batch", 4096)?.max(1);
    let workers = args.usize("serve-workers", 4)?.max(1);
    let requests = args.usize("serve-requests", 64)?.max(workers);
    let out_path = args.get_or("serve-out", "BENCH_serve.json").to_string();

    let mut rng = Rng::new(0x5E7E);
    // Two independent centroid sets: the boot model and the hot-swap.
    let models: Vec<Vec<f32>> = (0..2)
        .map(|_| (0..k * n).map(|_| rng.f32() * 100.0 - 50.0).collect())
        .collect();
    let points = blob_data(&mut rng, batch_rows, n, k);
    // Offline ground truth per served generation: any disagreement is a
    // correctness bug, not noise, so it fails the suite.
    let truth: Vec<Vec<u32>> = models
        .iter()
        .map(|c| {
            let mut counters = Counters::new();
            bigmeans::kernels::assign_only(&points, c, batch_rows, n, k, &mut counters).0
        })
        .collect();

    let boot = ModelArtifact::new(k, n, 1, 0.0, Json::Null, models[0].clone())
        .map_err(|e| e.to_string())?;
    let registry = ModelRegistry::new(boot);
    let server = Server::bind("127.0.0.1:0", Arc::clone(&registry), ServeOptions::default())
        .map_err(|e| e.to_string())?;
    let addr = server.local_addr().to_string();
    let runner = std::thread::spawn(move || server.run());
    let per_worker = requests / workers;
    let swap_after = per_worker / 2;
    eprintln!(
        "serve: {workers} workers × {per_worker} requests of {batch_rows}×{n} rows \
         (k={k}) against {addr}, hot-swap mid-run …"
    );

    let t0 = Instant::now();
    let results: Vec<(Vec<f64>, bool, u64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let addr = addr.clone();
                let points = &points;
                let truth = &truth;
                let models = &models;
                let registry = Arc::clone(&registry);
                scope.spawn(move || {
                    let mut client = Client::connect(&addr).expect("connect worker");
                    let mut lats = Vec::with_capacity(per_worker);
                    let mut identical = true;
                    let mut after_swap = 0u64;
                    for i in 0..per_worker {
                        if w == 0 && i == swap_after {
                            // In-process publish: the bench measures the
                            // swap's impact on live traffic; the file
                            // watcher path is exercised by the CI smoke.
                            let refreshed = ModelArtifact::new(
                                k,
                                n,
                                2,
                                0.0,
                                Json::Null,
                                models[1].clone(),
                            )
                            .expect("refreshed artifact");
                            registry.publish(refreshed);
                        }
                        let t = Instant::now();
                        let (generation, labels) =
                            client.assign(points, batch_rows, n).expect("assign");
                        lats.push(t.elapsed().as_secs_f64());
                        let want = &truth[(generation as usize - 1).min(truth.len() - 1)];
                        identical &= labels == *want;
                        if generation >= 2 {
                            after_swap += 1;
                        }
                    }
                    (lats, identical, after_swap)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("serve worker")).collect()
    });
    let wall = t0.elapsed().as_secs_f64();

    let mut client = Client::connect(&addr).map_err(|e| e.to_string())?;
    let (_, stats_json) = client.stats().map_err(|e| e.to_string())?;
    client.shutdown().map_err(|e| e.to_string())?;
    runner
        .join()
        .map_err(|_| "server thread panicked".to_string())?
        .map_err(|e| e.to_string())?;

    let bit_identical = results.iter().all(|(_, ok, _)| *ok);
    let answered_after_swap: u64 = results.iter().map(|(_, _, a)| a).sum();
    let mut lats: Vec<f64> =
        results.iter().flat_map(|(l, _, _)| l.iter().copied()).collect();
    lats.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let total = lats.len();
    let pct = |q: f64| -> f64 {
        if total == 0 {
            return 0.0;
        }
        lats[((q * total as f64).ceil() as usize).clamp(1, total) - 1]
    };
    if !bit_identical {
        return Err(
            "serve suite: a served batch diverged from the offline assign_only labels"
                .into(),
        );
    }
    if answered_after_swap == 0 {
        return Err("serve suite: no request observed the hot-swapped generation".into());
    }
    let qps = total as f64 / wall.max(1e-9);
    eprintln!(
        "serve: {total} responses in {wall:.3}s ({qps:.1} req/s, {:.3e} rows/s) | \
         p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms | {} swaps, {answered_after_swap} answers \
         from the swapped model | bit-identical: {bit_identical}",
        (total * batch_rows) as f64 / wall.max(1e-9),
        pct(0.50) * 1e3,
        pct(0.95) * 1e3,
        pct(0.99) * 1e3,
        registry.swaps(),
    );

    let server_stats =
        Json::parse(&stats_json).map_err(|e| format!("parse server stats: {e}"))?;
    let doc = obj(vec![
        ("k", num(k as f64)),
        ("n", num(n as f64)),
        ("batch_rows", num(batch_rows as f64)),
        ("workers", num(workers as f64)),
        ("requests", num(total as f64)),
        ("wall_secs", num(wall)),
        ("qps", num(qps)),
        ("rows_per_sec", num((total * batch_rows) as f64 / wall.max(1e-9))),
        ("p50_ms", num(pct(0.50) * 1e3)),
        ("p95_ms", num(pct(0.95) * 1e3)),
        ("p99_ms", num(pct(0.99) * 1e3)),
        ("swaps", num(registry.swaps() as f64)),
        ("answered_after_swap", num(answered_after_swap as f64)),
        ("bit_identical", Json::Bool(bit_identical)),
        ("server", server_stats),
    ]);
    std::fs::write(&out_path, doc.to_string() + "\n")
        .map_err(|e| format!("write {out_path}: {e}"))?;
    eprintln!("wrote {out_path}");
    Ok(())
}

fn main() {
    let args = match Args::parse_with_flags(std::env::args().skip(1), &["help"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("help") {
        eprintln!(
            "bench — engine, tuner, storage, and serving benchmarks\n\
             usage: bench [--suite assign|tuner|io|final|serve|all] [--m N] [--n N] \
             [--k N] [--iters N] [--shots N] [--s N] [--out PATH] [--tuner-out PATH] \
             [--io-m N] [--io-s N] [--io-samples N] [--block-rows N] [--io-out PATH] \
             [--final-m N] [--final-out PATH] [--serve-batch N] [--serve-workers N] \
             [--serve-requests N] [--serve-out PATH] [--compare BASELINE.json] \
             [--tolerance PCT]"
        );
        return;
    }
    let assign_suite = || -> Result<(), String> {
        let m = args.usize("m", 1_000_000)?;
        let n = args.usize("n", 16)?;
        let k = args.usize("k", 64)?;
        let iters = args.usize("iters", 5)?;
        let out_path = args.get_or("out", "BENCH_assign.json").to_string();
        if k == 0 || k > m {
            return Err(format!("k={k} out of range for m={m}"));
        }
        let full_evals = (m * k * iters) as f64;
        let mut rng = Rng::new(0xBE7C);
        eprintln!("generating {m}×{n} uniform + blob datasets (k={k}, iters={iters}) …");
        let uniform = uniform_data(&mut rng, m, n);
        let blobs = blob_data(&mut rng, m, n, k);

        let panel = PanelEngine;
        let bounded = BoundedEngine::default();
        let elkan = ElkanEngine::default();
        let hybrid = HybridEngine::default();
        let best_isa = detect_isa();
        let mut cases = Vec::new();
        for (data_name, data) in [("uniform", &uniform), ("blobs", &blobs)] {
            for (engine_name, engine) in [
                ("panel", &panel as &dyn KernelEngine),
                ("bounded", &bounded),
                ("elkan", &elkan),
                ("hybrid", &hybrid),
            ] {
                let name = format!("{engine_name}_{data_name}");
                eprint!("{name:<20} ");
                let c = time_engine(&name, engine, data, m, n, k, iters);
                eprintln!(
                    "{:>8.3}s  n_d {:.3e}  pruned {:.3e}  switches {}",
                    c.secs,
                    c.counters.distance_evals as f64,
                    c.counters.pruned_evals as f64,
                    c.counters.hybrid_switches
                );
                cases.push(c);
            }
            // Per-ISA A/B: the same panel arithmetic forced onto the
            // scalar backend — bit-identical by the dispatch contract,
            // slower at most.
            set_isa(DistanceIsa::Scalar).expect("scalar is always available");
            let name = format!("panel_scalar_{data_name}");
            eprint!("{name:<20} ");
            let c = time_engine(&name, &panel, data, m, n, k, iters);
            eprintln!(
                "{:>8.3}s  n_d {:.3e}  (forced scalar isa)",
                c.secs,
                c.counters.distance_evals as f64
            );
            cases.push(c);
            set_isa(best_isa).expect("detected isa must be available");
            // AVX-512 A/B: only on hosts that detect it — the row is
            // skipped (not failed) elsewhere, so it must never land in a
            // committed baseline (a missing baseline key would gate).
            if DistanceIsa::Avx512.available() {
                set_isa(DistanceIsa::Avx512).expect("avx512 detected as available");
                let name = format!("panel_avx512_{data_name}");
                eprint!("{name:<20} ");
                let c = time_engine(&name, &panel, data, m, n, k, iters);
                eprintln!(
                    "{:>8.3}s  n_d {:.3e}  (forced avx512 isa)",
                    c.secs,
                    c.counters.distance_evals as f64
                );
                cases.push(c);
                set_isa(best_isa).expect("detected isa must be available");
            } else {
                eprintln!("panel_avx512_{data_name}: skipped (avx512 not detected)");
            }
            let name = format!("reference_{data_name}");
            eprint!("{name:<20} ");
            let c = time_reference(&name, data, m, n, k, iters);
            eprintln!(
                "{:>8.3}s  n_d {:.3e}  (two-pass seed kernel)",
                c.secs,
                c.counters.distance_evals as f64
            );
            cases.push(c);
        }

        // Observability A/B: the same panel/uniform loop with metrics and
        // (unsinked) tracing enabled. Observers are a branch on a relaxed
        // atomic when off and buffer-only when on, so the delta must stay
        // within run-to-run noise.
        let obs_off =
            cases.iter().find(|c| c.name == "panel_uniform").map(|c| c.secs).unwrap_or(0.0);
        obs::metrics().enable();
        obs::tracer().enable_unsinked();
        let name = "panel_uniform_obs";
        eprint!("{name:<20} ");
        let c = time_engine(name, &panel, &uniform, m, n, k, iters);
        obs::tracer().disable_and_clear();
        obs::metrics().disable();
        let obs_ratio = c.secs / obs_off.max(1e-12);
        eprintln!(
            "{:>8.3}s  n_d {:.3e}  (metrics + tracing on; {obs_ratio:.3}× vs disabled)",
            c.secs, c.counters.distance_evals as f64
        );
        cases.push(c);

        // Flight-recorder A/B: the recorder alone (no metrics, no trace
        // file) — the always-on configuration `cluster` ships with. Spans
        // route into the bounded rings via the tracer's recorder tap, so
        // this measures the actual shipped hot path; the overhead row must
        // also stay within run-to-run noise.
        obs::recorder().enable_unsinked();
        let name = "panel_uniform_recorder";
        eprint!("{name:<20} ");
        let c = time_engine(name, &panel, &uniform, m, n, k, iters);
        obs::recorder().disable_and_clear();
        let recorder_ratio = c.secs / obs_off.max(1e-12);
        eprintln!(
            "{:>8.3}s  n_d {:.3e}  (flight recorder on; {recorder_ratio:.3}× vs disabled)",
            c.secs, c.counters.distance_evals as f64
        );
        cases.push(c);

        let find = |name: &str| cases.iter().find(|c| c.name == name).unwrap();
        let bounded_blobs = find("bounded_blobs");
        let eval_ratio = full_evals / (bounded_blobs.counters.distance_evals as f64).max(1.0);
        let elkan_blobs = find("elkan_blobs");
        let elkan_ratio = full_evals / (elkan_blobs.counters.distance_evals as f64).max(1.0);
        let fused_speedup = find("reference_uniform").secs / find("panel_uniform").secs.max(1e-12);
        let simd_speedup =
            find("panel_scalar_uniform").secs / find("panel_uniform").secs.max(1e-12);
        eprintln!(
            "bounded/blobs eval reduction: {eval_ratio:.2}× \
             | elkan/blobs: {elkan_ratio:.2}× \
             | fused panel vs seed kernel (uniform): {fused_speedup:.2}× \
             | {} vs scalar (uniform): {simd_speedup:.2}×",
            best_isa.name()
        );

        let mut entries = vec![
            ("m", num(m as f64)),
            ("n", num(n as f64)),
            ("k", num(k as f64)),
            ("iters", num(iters as f64)),
            ("isa", s(active_isa().name())),
            ("full_evals", num(full_evals)),
            ("cases", arr(cases.iter().map(case_json).collect())),
            ("bounded_blobs_eval_reduction", num(eval_ratio)),
            ("elkan_blobs_eval_reduction", num(elkan_ratio)),
            ("fused_vs_reference_uniform_speedup", num(fused_speedup)),
            ("simd_vs_scalar_uniform_speedup", num(simd_speedup)),
            ("obs_enabled_vs_disabled_ratio", num(obs_ratio)),
            ("recorder_enabled_vs_disabled_ratio", num(recorder_ratio)),
        ];
        // Conditional summary key: present only when the avx512 rows ran.
        if let Some(c) = cases.iter().find(|c| c.name == "panel_avx512_uniform") {
            let avx512_speedup = find("panel_scalar_uniform").secs / c.secs.max(1e-12);
            eprintln!("avx512 vs scalar (uniform): {avx512_speedup:.2}×");
            entries.push(("avx512_vs_scalar_uniform_speedup", num(avx512_speedup)));
        }
        let doc = obj(entries);
        std::fs::write(&out_path, doc.to_string() + "\n")
            .map_err(|e| format!("write {out_path}: {e}"))?;
        eprintln!("wrote {out_path}");
        Ok(())
    };
    let result = match args.choice("suite", &["assign", "tuner", "io", "final", "serve", "all"])
    {
        Ok("tuner") => tuner_suite(&args),
        Ok("io") => io_suite(&args),
        Ok("final") => final_suite(&args),
        Ok("serve") => serve_suite(&args),
        Ok("all") => assign_suite()
            .and_then(|()| tuner_suite(&args))
            .and_then(|()| io_suite(&args))
            .and_then(|()| final_suite(&args))
            .and_then(|()| serve_suite(&args)),
        Ok(_) => assign_suite(),
        Err(e) => Err(e),
    };
    let result = result.and_then(|()| maybe_compare(&args));
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// `--compare BASELINE.json [--tolerance PCT]`: after the suite runs,
/// diff its freshly written output document against a committed baseline
/// and exit nonzero on any perf leaf beyond the tolerance — CI's bench
/// regression gate.
fn maybe_compare(args: &Args) -> Result<(), String> {
    let Some(baseline_path) = args.get("compare") else {
        return Ok(());
    };
    let tolerance = args.f64("tolerance", 25.0)?;
    let suite = args.choice("suite", &["assign", "tuner", "io", "final", "serve", "all"])?;
    let candidate_path = match suite {
        "tuner" => args.get_or("tuner-out", "BENCH_tuner.json"),
        "io" => args.get_or("io-out", "BENCH_io.json"),
        "final" => args.get_or("final-out", "BENCH_final.json"),
        "serve" => args.get_or("serve-out", "BENCH_serve.json"),
        "all" => {
            return Err(
                "--compare gates one suite's document; run it per suite, not --suite all"
                    .into(),
            );
        }
        _ => args.get_or("out", "BENCH_assign.json"),
    };
    let read_doc = |p: &str| -> Result<Json, String> {
        let text = std::fs::read_to_string(p).map_err(|e| format!("read {p}: {e}"))?;
        Json::parse(&text).map_err(|e| format!("{p}: {e}"))
    };
    let baseline = read_doc(baseline_path)?;
    let candidate = read_doc(candidate_path)?;
    let regressions =
        bigmeans::bench_harness::compare::compare_docs(&baseline, &candidate, tolerance);
    if regressions.is_empty() {
        eprintln!(
            "compare: ok — {candidate_path} within {tolerance}% of {baseline_path} on every \
             perf leaf"
        );
        return Ok(());
    }
    for r in &regressions {
        eprintln!("regression: {r}");
    }
    Err(format!(
        "{} perf regression(s) in {candidate_path} vs {baseline_path} (tolerance {tolerance}%)",
        regressions.len()
    ))
}
