//! # Big-means: scalable K-means clustering for big data
//!
//! Production-grade reproduction of
//! *"Big-means: Less is More for K-means Clustering"* /
//! *"How to use K-means for big data clustering?"* (Mussabayev, Mladenovic,
//! Jarboui, Mussabayev; Pattern Recognition 2022, DOI 10.1016/j.patcog.2022.109269),
//! built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: chunk sampling, incumbent
//!   management, degenerate-centroid reinitialisation, sequential and
//!   parallel chunk pipelines, streaming ingestion, metrics, CLI.
//! * **Layer 2 (python/compile/model.py)** — the MSSC local search (Lloyd
//!   iterations + K-means++ seeding) as a JAX computation, AOT-lowered to
//!   HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — the assignment-step hot spot
//!   (pairwise squared distances + argmin + per-cluster reduction) as a
//!   Pallas kernel, validated against a pure-jnp oracle.
//!
//! The runtime loads the AOT artifacts via the PJRT C API (behind the
//! `pjrt` cargo feature) — python never runs on the clustering path. A
//! native Rust kernel substrate ([`kernels`]) provides the same primitives
//! for arbitrary shapes and for the baseline algorithms ([`baselines`])
//! the paper compares against.
//!
//! ## Quickstart
//!
//! ```no_run
//! use bigmeans::{BigMeans, BigMeansConfig, Dataset};
//!
//! let data = Dataset::from_vec("demo", vec![0.0; 1000 * 4], 1000, 4);
//! let config = BigMeansConfig::new(/*k=*/ 8, /*chunk_size=*/ 256);
//! let result = BigMeans::new(config).run(&data).unwrap();
//! println!("SSE = {}", result.objective);
//! ```
//!
//! ## Out-of-core clustering
//!
//! Every pipeline consumes a [`DataSource`] — the paper's decomposition
//! principle means Big-means only ever touches bounded chunks, so the
//! dataset never has to fit in RAM. Convert once to the `.bmx` flat binary
//! format (documented in [`data`]), then cluster through the mmap backend:
//!
//! ```no_run
//! use bigmeans::{BigMeans, BigMeansConfig, BmxSource};
//!
//! bigmeans::data::csv_to_bmx("huge.csv".as_ref(), "huge.bmx".as_ref()).unwrap();
//! let source = BmxSource::open("huge.bmx".as_ref()).unwrap();
//! let result = BigMeans::new(BigMeansConfig::new(25, 4096)).run(&source).unwrap();
//! println!("SSE = {}", result.objective);
//! ```
//!
//! Backends are value-identical: a seeded run yields bit-for-bit the same
//! objective whether the bytes come from RAM, an mmap, or buffered reads
//! (see `tests/integration_out_of_core.rs` and `examples/out_of_core.rs`).

pub mod baselines;
pub mod bench_harness;
pub mod coordinator;
pub mod data;
pub mod kernels;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod tuner;
pub mod util;

pub use coordinator::bigmeans::{BigMeans, BigMeansResult};
pub use coordinator::config::{BigMeansConfig, DataBackend};
pub use tuner::{RaceResult, TunerConfig};
pub use data::bmx::BmxSource;
pub use data::csv_source::CsvSource;
pub use data::dataset::Dataset;
pub use data::source::DataSource;
pub use serve::{Client, ModelArtifact, ModelRegistry, Server, ServeOptions};
pub use store::{BlockStore, BlockWriter, Codec, Dtype, StoreOptions};
