//! The model registry: an `ArcSwap`-style atomic hot-swap point.
//!
//! Request handlers grab `Arc<ServingModel>` snapshots; a publish builds
//! the new model off to the side and swaps one pointer under a
//! poison-recovering write lock held for nanoseconds. In-flight requests
//! keep the `Arc` they cloned — **no request is ever dropped or torn by a
//! swap**; each one is answered entirely by whichever generation it
//! snapshotted.
//!
//! The swap **generation counter** is the registry's logical clock: it
//! starts at 1 for the boot model and increments per publish. It is
//! deliberately distinct from [`ModelArtifact::generation`] (the
//! *publisher's* ordinal): a daemon restarted against generation-40
//! centroids still begins at swap generation 1.
//!
//! [`spawn_watcher`] is the file half of the stream→registry publish
//! contract: it polls the artifact path's `(len, mtime)` stat, reloads on
//! change, and publishes only when the *content identity*
//! `(artifact.generation, payload_crc)` actually differs — a rewritten
//! but identical file swaps nothing. Load errors (torn write caught by
//! CRC, transient I/O) are logged and retried on the next poll, never
//! fatal: robustness-first, like the rest of the daemon.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, SystemTime};

use crate::kernels::distance::sq_norm;
use crate::obs;
use crate::serve::artifact::ModelArtifact;
use crate::util::json::{self, Json};
use crate::util::sync::{lock_recover, read_recover, write_recover};

/// An immutable, query-ready model snapshot.
pub struct ServingModel {
    /// The loaded artifact (centroids + geometry + provenance).
    pub artifact: ModelArtifact,
    /// Registry swap generation this model was installed as (1 = boot).
    pub generation: u64,
    /// Per-centroid squared norms, precomputed **in centroid order with
    /// the same [`sq_norm`] arithmetic as `assign_only`** — the
    /// precondition for served labels being bit-identical to the offline
    /// pass.
    pub c_sq: Vec<f32>,
}

impl ServingModel {
    fn new(artifact: ModelArtifact, generation: u64) -> ServingModel {
        let (k, n) = (artifact.k, artifact.n);
        let c_sq: Vec<f32> =
            (0..k).map(|j| sq_norm(&artifact.centroids[j * n..(j + 1) * n])).collect();
        ServingModel { artifact, generation, c_sq }
    }
}

/// Newest swap-history entries kept (older ones roll off).
pub const SWAP_HISTORY_CAP: usize = 64;

/// One recorded model install — the boot model or a hot-swap.
#[derive(Clone, Debug)]
pub struct SwapEvent {
    /// Registry swap generation installed (1 = boot).
    pub generation: u64,
    /// The publisher's ordinal carried by the artifact.
    pub artifact_generation: u64,
    /// Training objective recorded in the artifact.
    pub objective: f64,
    /// UTC wall-clock timestamp of the install.
    pub at: String,
}

impl SwapEvent {
    fn of(artifact: &ModelArtifact, generation: u64) -> SwapEvent {
        SwapEvent {
            generation,
            artifact_generation: artifact.generation,
            objective: artifact.objective,
            at: crate::obs::log::timestamp_utc(),
        }
    }

    fn to_json(&self) -> Json {
        json::obj(vec![
            ("generation", json::num(self.generation as f64)),
            ("artifact_generation", json::num(self.artifact_generation as f64)),
            ("objective", json::num(self.objective)),
            ("at", json::s(&self.at)),
        ])
    }
}

/// Atomic hot-swap registry of the currently served model.
pub struct ModelRegistry {
    current: RwLock<Arc<ServingModel>>,
    generation: AtomicU64,
    /// Bounded install log (boot + hot-swaps), newest last — surfaced by
    /// `GET /healthz` so "what swapped, when, to what objective" is
    /// answerable without daemon logs.
    history: Mutex<Vec<SwapEvent>>,
    m_generation: obs::Gauge,
    m_swaps: obs::Counter,
}

impl ModelRegistry {
    /// Boot the registry with its first model (swap generation 1).
    pub fn new(artifact: ModelArtifact) -> Arc<ModelRegistry> {
        let boot = SwapEvent::of(&artifact, 1);
        let model = Arc::new(ServingModel::new(artifact, 1));
        let m = obs::metrics();
        let m_generation = m.gauge(
            "bigmeans_model_generation",
            "Swap generation of the currently served model (1 = boot)",
            &[],
        );
        m_generation.set(1.0);
        Arc::new(ModelRegistry {
            current: RwLock::new(model),
            generation: AtomicU64::new(1),
            history: Mutex::new(vec![boot]),
            m_generation,
            m_swaps: m.counter(
                "bigmeans_model_swaps_total",
                "Model hot-swaps performed since daemon boot",
                &[],
            ),
        })
    }

    /// Snapshot the current model: one short read lock to clone an `Arc`.
    /// The caller's snapshot stays valid across any number of swaps.
    pub fn current(&self) -> Arc<ServingModel> {
        Arc::clone(&read_recover(&self.current))
    }

    /// Install a new model atomically; returns its swap generation. The
    /// expensive work (c_sq precompute) happens before the write lock,
    /// which is held only for the pointer swap.
    pub fn publish(&self, artifact: ModelArtifact) -> u64 {
        let generation = self.generation.fetch_add(1, Ordering::SeqCst) + 1;
        let event = SwapEvent::of(&artifact, generation);
        let model = Arc::new(ServingModel::new(artifact, generation));
        *write_recover(&self.current) = model;
        {
            let mut history = lock_recover(&self.history);
            if history.len() >= SWAP_HISTORY_CAP {
                history.remove(0);
            }
            history.push(event);
        }
        self.m_generation.set(generation as f64);
        self.m_swaps.inc();
        generation
    }

    /// The bounded install log (boot + hot-swaps), newest last, as a JSON
    /// array — the `/healthz` swap-history surface.
    pub fn history_json(&self) -> Json {
        let history = lock_recover(&self.history);
        json::arr(history.iter().map(SwapEvent::to_json).collect())
    }

    /// Current swap generation (1 = still the boot model).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }

    /// Hot-swaps performed since boot.
    pub fn swaps(&self) -> u64 {
        self.generation().saturating_sub(1)
    }
}

/// File stat identity used to cheaply detect "the artifact may have
/// changed" before paying a full load + CRC validation.
fn stat_of(path: &Path) -> Option<(u64, SystemTime)> {
    let meta = std::fs::metadata(path).ok()?;
    Some((meta.len(), meta.modified().ok()?))
}

/// Watch `path` and publish refreshed models into `registry` until `stop`
/// is set. `initial_identity` is the `(artifact generation, payload CRC)`
/// of the model the registry booted with, so an unchanged file on the
/// first poll publishes nothing.
///
/// The poll loop sleeps in small increments so a stop request is honoured
/// promptly even with a long `interval`.
pub fn spawn_watcher(
    registry: Arc<ModelRegistry>,
    path: PathBuf,
    interval: Duration,
    stop: Arc<AtomicBool>,
    initial_identity: (u64, u32),
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("bigmeans-model-watcher".into())
        .spawn(move || {
            let mut last_stat = stat_of(&path);
            let mut last_identity = initial_identity;
            let tick = Duration::from_millis(25).min(interval.max(Duration::from_millis(1)));
            let mut elapsed = Duration::ZERO;
            while !stop.load(Ordering::SeqCst) {
                std::thread::sleep(tick);
                elapsed += tick;
                if elapsed < interval {
                    continue;
                }
                elapsed = Duration::ZERO;
                let stat = stat_of(&path);
                if stat == last_stat || stat.is_none() {
                    continue;
                }
                let _span = obs::tracer().span("serve.watch", "reload");
                match ModelArtifact::load(&path) {
                    Err(e) => {
                        // Torn write or transient I/O: keep serving the
                        // old model, retry on the next poll.
                        crate::log_warn!("serve.watcher", "reload deferred: {e}");
                    }
                    Ok(artifact) => {
                        last_stat = stat;
                        let identity = (artifact.generation, artifact.payload_crc());
                        if identity == last_identity {
                            continue; // rewritten but identical — no swap
                        }
                        let current_n = registry.current().artifact.n;
                        if artifact.n != current_n {
                            crate::log_warn!(
                                "serve.watcher",
                                "rejected publish: dims changed from {current_n} to {} \
                                 (restart the daemon to change the served schema)",
                                artifact.n
                            );
                            continue;
                        }
                        last_identity = identity;
                        let generation = registry.publish(artifact);
                        crate::log_info!(
                            "serve.watcher",
                            "hot-swapped to swap generation {generation}"
                        );
                    }
                }
            }
        })
        .expect("spawn model watcher")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn artifact(gen: u64, centroids: Vec<f32>, n: usize) -> ModelArtifact {
        let k = centroids.len() / n;
        ModelArtifact::new(k, n, gen, 1.0, Json::Null, centroids).unwrap()
    }

    #[test]
    fn publish_swaps_atomically_and_counts_generations() {
        let reg = ModelRegistry::new(artifact(1, vec![0.0, 0.0, 1.0, 1.0], 2));
        assert_eq!(reg.generation(), 1);
        assert_eq!(reg.swaps(), 0);
        let before = reg.current();
        assert_eq!(before.generation, 1);
        let g = reg.publish(artifact(2, vec![5.0, 5.0, 6.0, 6.0], 2));
        assert_eq!(g, 2);
        assert_eq!(reg.generation(), 2);
        assert_eq!(reg.swaps(), 1);
        // The old snapshot is still fully usable — no request it answers
        // can be torn by the swap.
        assert_eq!(before.artifact.centroids, vec![0.0, 0.0, 1.0, 1.0]);
        assert_eq!(reg.current().artifact.centroids, vec![5.0, 5.0, 6.0, 6.0]);
    }

    #[test]
    fn swap_history_is_bounded_and_ordered() {
        let reg = ModelRegistry::new(artifact(1, vec![0.0, 0.0], 2));
        for g in 0..(SWAP_HISTORY_CAP as u64 + 10) {
            reg.publish(artifact(g + 2, vec![g as f32, 0.0], 2));
        }
        let doc = reg.history_json();
        let entries = doc.as_arr().expect("history is a JSON array");
        assert_eq!(entries.len(), SWAP_HISTORY_CAP, "history must stay bounded");
        let gens: Vec<f64> = entries
            .iter()
            .map(|e| e.get("generation").and_then(|v| v.as_f64()).unwrap())
            .collect();
        assert!(gens.windows(2).all(|w| w[1] == w[0] + 1.0), "newest last: {gens:?}");
        assert_eq!(
            *gens.last().unwrap() as u64,
            reg.generation(),
            "last entry is the serving generation"
        );
        for e in entries {
            assert!(e.get("at").and_then(|v| v.as_str()).is_some());
            assert!(e.get("objective").and_then(|v| v.as_f64()).is_some());
            assert!(e.get("artifact_generation").and_then(|v| v.as_f64()).is_some());
        }
    }

    #[test]
    fn c_sq_matches_assign_only_preamble() {
        let cs = vec![1.0f32, 2.0, -3.0, 0.5];
        let reg = ModelRegistry::new(artifact(1, cs.clone(), 2));
        let model = reg.current();
        let want: Vec<f32> = (0..2).map(|j| sq_norm(&cs[j * 2..(j + 1) * 2])).collect();
        let same =
            model.c_sq.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "c_sq must be the exact assign_only preamble");
    }

    #[test]
    fn watcher_publishes_a_refreshed_artifact() {
        let dir = std::env::temp_dir().join("bigmeans_serve_registry_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{}_watch.bmm", std::process::id()));
        let a1 = artifact(1, vec![0.0, 0.0], 2);
        a1.save(&path).unwrap();
        let identity = (a1.generation, a1.payload_crc());
        let reg = ModelRegistry::new(ModelArtifact::load(&path).unwrap());
        let stop = Arc::new(AtomicBool::new(false));
        let handle = spawn_watcher(
            Arc::clone(&reg),
            path.clone(),
            Duration::from_millis(30),
            Arc::clone(&stop),
            identity,
        );
        // Give the watcher a first poll on the unchanged file.
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(reg.generation(), 1, "unchanged file must not swap");
        // Publish a refreshed model (larger k → different byte length, so
        // the stat check fires even on coarse-mtime filesystems).
        artifact(2, vec![9.0, 9.0, 1.0, 1.0], 2).save(&path).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while reg.generation() < 2 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(reg.generation(), 2, "watcher must pick up the new artifact");
        assert_eq!(reg.current().artifact.centroids, vec![9.0, 9.0, 1.0, 1.0]);
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
        let _ = std::fs::remove_file(&path);
    }
}
