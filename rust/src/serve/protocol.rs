//! Length-prefixed TCP wire format (`std::net` only) and the [`Client`].
//!
//! Every frame is a `u32` little-endian byte length followed by the body;
//! one request frame yields exactly one response frame on the same
//! connection, in order. All multi-byte integers and floats are
//! little-endian.
//!
//! ## Request body
//!
//! ```text
//! [op u8][rows u32][n u32][points: rows × n × f32]
//! ```
//!
//! `rows = n = 0` (no points) for the pointless ops (stats, ping,
//! shutdown, dump-diagnostics).
//!
//! ## Response body
//!
//! ```text
//! [status u8][op u8][generation u64][payload…]
//! ```
//!
//! `generation` is the registry swap generation of the model that
//! answered — the hot-swap observability hook. Payload by op:
//! assign → `[rows u32][labels u32 × rows]`;
//! score  → `[rows u32][labels u32 × rows][dists f32 × rows][objective f64]`
//! (objective = f64 row-order sum of the dists);
//! stats / dump-diagnostics → `[len u32][JSON bytes]`;
//! ping / shutdown → empty. Error status replaces the payload with
//! `[len u32][message bytes]`.
//!
//! Clean EOF before a frame's first length byte is a normal disconnect
//! ([`read_request`] returns `None`); EOF mid-frame is an error — there
//! is deliberately no resynchronisation, a torn frame kills the
//! connection, never desyncs it.

use std::io::{self, Read, Write};
use std::net::TcpStream;

use crate::util::error::Result;
use crate::{anyhow, bail};

/// Frame size cap — rejects absurd lengths before allocating.
pub const MAX_FRAME: usize = 1 << 28;

const OP_ASSIGN: u8 = 1;
const OP_SCORE: u8 = 2;
const OP_STATS: u8 = 3;
const OP_PING: u8 = 4;
const OP_SHUTDOWN: u8 = 5;
const OP_DUMP_DIAGNOSTICS: u8 = 6;

const STATUS_OK: u8 = 0;
const STATUS_ERR: u8 = 1;

/// A decoded request frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Batched nearest-centroid labels for `rows × n` points.
    Assign { rows: usize, n: usize, points: Vec<f32> },
    /// Labels + squared distances + batch objective.
    Score { rows: usize, n: usize, points: Vec<f32> },
    /// Server counters as JSON.
    Stats,
    /// Liveness probe.
    Ping,
    /// Ask the daemon to stop accepting and drain.
    Shutdown,
    /// Flight-recorder diagnostics dump as JSON (on-demand triage of a
    /// live daemon — the third dump trigger besides panic and SIGTERM).
    DumpDiagnostics,
}

impl Request {
    fn op(&self) -> u8 {
        match self {
            Request::Assign { .. } => OP_ASSIGN,
            Request::Score { .. } => OP_SCORE,
            Request::Stats => OP_STATS,
            Request::Ping => OP_PING,
            Request::Shutdown => OP_SHUTDOWN,
            Request::DumpDiagnostics => OP_DUMP_DIAGNOSTICS,
        }
    }
}

/// A decoded response frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    /// Registry swap generation of the model that answered.
    pub generation: u64,
    pub payload: ResponsePayload,
}

/// Response payload by operation.
#[derive(Clone, Debug, PartialEq)]
pub enum ResponsePayload {
    Assign { labels: Vec<u32> },
    Score { labels: Vec<u32>, dists: Vec<f32>, objective: f64 },
    Stats { json: String },
    Diagnostics { json: String },
    Pong,
    ShuttingDown,
    Error { message: String },
}

fn bad_frame(what: impl std::fmt::Display) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("bad frame: {what}"))
}

/// Fill `buf` exactly; `Ok(false)` on clean EOF at the first byte,
/// an error on EOF anywhere later (a torn frame).
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> io::Result<bool> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                return Err(bad_frame("EOF mid-frame"));
            }
            Ok(got) => filled += got,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

fn read_frame(r: &mut impl Read) -> io::Result<Option<Vec<u8>>> {
    let mut len_bytes = [0u8; 4];
    if !read_exact_or_eof(r, &mut len_bytes)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len_bytes) as usize;
    if len > MAX_FRAME {
        return Err(bad_frame(format!("length {len} exceeds cap {MAX_FRAME}")));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Some(body))
}

fn write_frame(w: &mut impl Write, body: &[u8]) -> io::Result<()> {
    debug_assert!(body.len() <= MAX_FRAME);
    let mut frame = Vec::with_capacity(4 + body.len());
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(body);
    w.write_all(&frame)
}

/// Read one request frame; `None` on clean disconnect.
pub fn read_request(r: &mut impl Read) -> io::Result<Option<Request>> {
    let Some(body) = read_frame(r)? else { return Ok(None) };
    if body.len() < 9 {
        return Err(bad_frame("request shorter than its fixed fields"));
    }
    let op = body[0];
    let rows = u32::from_le_bytes(body[1..5].try_into().unwrap()) as usize;
    let n = u32::from_le_bytes(body[5..9].try_into().unwrap()) as usize;
    let want = rows
        .checked_mul(n)
        .and_then(|v| v.checked_mul(4))
        .and_then(|v| v.checked_add(9))
        .ok_or_else(|| bad_frame("request geometry overflows"))?;
    if body.len() != want {
        return Err(bad_frame(format!(
            "request holds {} bytes, {rows}x{n} points need {want}",
            body.len()
        )));
    }
    let points: Vec<f32> = body[9..]
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
        .collect();
    match op {
        OP_ASSIGN => Ok(Some(Request::Assign { rows, n, points })),
        OP_SCORE => Ok(Some(Request::Score { rows, n, points })),
        OP_STATS if rows == 0 && n == 0 => Ok(Some(Request::Stats)),
        OP_PING if rows == 0 && n == 0 => Ok(Some(Request::Ping)),
        OP_SHUTDOWN if rows == 0 && n == 0 => Ok(Some(Request::Shutdown)),
        OP_DUMP_DIAGNOSTICS if rows == 0 && n == 0 => {
            Ok(Some(Request::DumpDiagnostics))
        }
        _ => Err(bad_frame(format!("unknown op {op} (rows={rows}, n={n})"))),
    }
}

/// Encode + send one request frame.
pub fn write_request(w: &mut impl Write, req: &Request) -> io::Result<()> {
    let (rows, n, points): (usize, usize, &[f32]) = match req {
        Request::Assign { rows, n, points } | Request::Score { rows, n, points } => {
            (*rows, *n, points)
        }
        _ => (0, 0, &[]),
    };
    let mut body = Vec::with_capacity(9 + points.len() * 4);
    body.push(req.op());
    body.extend_from_slice(&(rows as u32).to_le_bytes());
    body.extend_from_slice(&(n as u32).to_le_bytes());
    for v in points {
        body.extend_from_slice(&v.to_le_bytes());
    }
    write_frame(w, &body)
}

/// Encode + send one response frame.
pub fn write_response(w: &mut impl Write, resp: &Response) -> io::Result<()> {
    let mut body = Vec::new();
    let (status, op) = match &resp.payload {
        ResponsePayload::Assign { .. } => (STATUS_OK, OP_ASSIGN),
        ResponsePayload::Score { .. } => (STATUS_OK, OP_SCORE),
        ResponsePayload::Stats { .. } => (STATUS_OK, OP_STATS),
        ResponsePayload::Diagnostics { .. } => (STATUS_OK, OP_DUMP_DIAGNOSTICS),
        ResponsePayload::Pong => (STATUS_OK, OP_PING),
        ResponsePayload::ShuttingDown => (STATUS_OK, OP_SHUTDOWN),
        ResponsePayload::Error { .. } => (STATUS_ERR, 0),
    };
    body.push(status);
    body.push(op);
    body.extend_from_slice(&resp.generation.to_le_bytes());
    match &resp.payload {
        ResponsePayload::Assign { labels } => {
            body.extend_from_slice(&(labels.len() as u32).to_le_bytes());
            for l in labels {
                body.extend_from_slice(&l.to_le_bytes());
            }
        }
        ResponsePayload::Score { labels, dists, objective } => {
            body.extend_from_slice(&(labels.len() as u32).to_le_bytes());
            for l in labels {
                body.extend_from_slice(&l.to_le_bytes());
            }
            for d in dists {
                body.extend_from_slice(&d.to_le_bytes());
            }
            body.extend_from_slice(&objective.to_le_bytes());
        }
        ResponsePayload::Stats { json } | ResponsePayload::Diagnostics { json } => {
            body.extend_from_slice(&(json.len() as u32).to_le_bytes());
            body.extend_from_slice(json.as_bytes());
        }
        ResponsePayload::Pong | ResponsePayload::ShuttingDown => {}
        ResponsePayload::Error { message } => {
            body.extend_from_slice(&(message.len() as u32).to_le_bytes());
            body.extend_from_slice(message.as_bytes());
        }
    }
    write_frame(w, &body)
}

fn take_u32(body: &[u8], at: usize) -> io::Result<u32> {
    body.get(at..at + 4)
        .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
        .ok_or_else(|| bad_frame("response too short"))
}

/// Read + decode one response frame (EOF is an error — the caller just
/// sent a request, so a response is owed).
pub fn read_response(r: &mut impl Read) -> io::Result<Response> {
    let body = read_frame(r)?.ok_or_else(|| bad_frame("EOF awaiting response"))?;
    if body.len() < 10 {
        return Err(bad_frame("response shorter than its fixed fields"));
    }
    let status = body[0];
    let op = body[1];
    let generation = u64::from_le_bytes(body[2..10].try_into().unwrap());
    let rest = &body[10..];
    if status == STATUS_ERR {
        let len = take_u32(rest, 0)? as usize;
        let raw = rest.get(4..4 + len).ok_or_else(|| bad_frame("error text truncated"))?;
        let message = String::from_utf8_lossy(raw).into_owned();
        return Ok(Response { generation, payload: ResponsePayload::Error { message } });
    }
    let payload = match op {
        OP_ASSIGN | OP_SCORE => {
            let rows = take_u32(rest, 0)? as usize;
            let labels_end = 4 + rows * 4;
            let raw = rest
                .get(4..labels_end)
                .ok_or_else(|| bad_frame("labels truncated"))?;
            let labels: Vec<u32> = raw
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            if op == OP_ASSIGN {
                ResponsePayload::Assign { labels }
            } else {
                let dists_end = labels_end + rows * 4;
                let raw = rest
                    .get(labels_end..dists_end)
                    .ok_or_else(|| bad_frame("dists truncated"))?;
                let dists: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                    .collect();
                let raw = rest
                    .get(dists_end..dists_end + 8)
                    .ok_or_else(|| bad_frame("objective truncated"))?;
                let objective = f64::from_le_bytes(raw.try_into().unwrap());
                ResponsePayload::Score { labels, dists, objective }
            }
        }
        OP_STATS | OP_DUMP_DIAGNOSTICS => {
            let len = take_u32(rest, 0)? as usize;
            let raw =
                rest.get(4..4 + len).ok_or_else(|| bad_frame("stats text truncated"))?;
            let json = String::from_utf8_lossy(raw).into_owned();
            if op == OP_STATS {
                ResponsePayload::Stats { json }
            } else {
                ResponsePayload::Diagnostics { json }
            }
        }
        OP_PING => ResponsePayload::Pong,
        OP_SHUTDOWN => ResponsePayload::ShuttingDown,
        _ => return Err(bad_frame(format!("unknown response op {op}"))),
    };
    Ok(Response { generation, payload })
}

/// Blocking client for the serve protocol — used by `--mode query`, the
/// bench suite, and the integration tests.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    /// Connect to a daemon at `addr` (e.g. `127.0.0.1:7171`).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow!("connect to {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client { stream })
    }

    fn roundtrip(&mut self, req: &Request) -> Result<Response> {
        write_request(&mut self.stream, req)?;
        let resp = read_response(&mut self.stream)?;
        if let ResponsePayload::Error { message } = &resp.payload {
            bail!("server error: {message}");
        }
        Ok(resp)
    }

    /// Batched nearest-centroid query: `(generation, labels)`.
    pub fn assign(&mut self, points: &[f32], rows: usize, n: usize) -> Result<(u64, Vec<u32>)> {
        if points.len() != rows * n {
            bail!("assign: {} values for {rows}x{n} points", points.len());
        }
        let req = Request::Assign { rows, n, points: points.to_vec() };
        match self.roundtrip(&req)? {
            Response { generation, payload: ResponsePayload::Assign { labels } } => {
                Ok((generation, labels))
            }
            other => bail!("assign: mismatched response {:?}", other.payload),
        }
    }

    /// Batched score query: `(generation, labels, dists, objective)`.
    pub fn score(
        &mut self,
        points: &[f32],
        rows: usize,
        n: usize,
    ) -> Result<(u64, Vec<u32>, Vec<f32>, f64)> {
        if points.len() != rows * n {
            bail!("score: {} values for {rows}x{n} points", points.len());
        }
        let req = Request::Score { rows, n, points: points.to_vec() };
        match self.roundtrip(&req)? {
            Response {
                generation,
                payload: ResponsePayload::Score { labels, dists, objective },
            } => Ok((generation, labels, dists, objective)),
            other => bail!("score: mismatched response {:?}", other.payload),
        }
    }

    /// Server counters as `(generation, JSON text)`.
    pub fn stats(&mut self) -> Result<(u64, String)> {
        match self.roundtrip(&Request::Stats)? {
            Response { generation, payload: ResponsePayload::Stats { json } } => {
                Ok((generation, json))
            }
            other => bail!("stats: mismatched response {:?}", other.payload),
        }
    }

    /// Flight-recorder diagnostics dump as `(generation, JSON text)`.
    pub fn dump_diagnostics(&mut self) -> Result<(u64, String)> {
        match self.roundtrip(&Request::DumpDiagnostics)? {
            Response { generation, payload: ResponsePayload::Diagnostics { json } } => {
                Ok((generation, json))
            }
            other => bail!("dump-diagnostics: mismatched response {:?}", other.payload),
        }
    }

    /// Liveness probe; returns the serving generation.
    pub fn ping(&mut self) -> Result<u64> {
        Ok(self.roundtrip(&Request::Ping)?.generation)
    }

    /// Ask the daemon to stop; returns the final serving generation.
    pub fn shutdown(&mut self) -> Result<u64> {
        Ok(self.roundtrip(&Request::Shutdown)?.generation)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req_roundtrip(req: Request) {
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        let mut r: &[u8] = &buf;
        let back = read_request(&mut r).unwrap().expect("frame present");
        assert_eq!(back, req);
        assert!(r.is_empty(), "exactly one frame consumed");
    }

    fn resp_roundtrip(resp: Response) {
        let mut buf = Vec::new();
        write_response(&mut buf, &resp).unwrap();
        let mut r: &[u8] = &buf;
        let back = read_response(&mut r).unwrap();
        assert_eq!(back, resp);
        assert!(r.is_empty());
    }

    #[test]
    fn requests_roundtrip() {
        req_roundtrip(Request::Assign { rows: 3, n: 2, points: vec![1.0; 6] });
        req_roundtrip(Request::Score {
            rows: 2,
            n: 3,
            points: vec![0.5, -1.25, 3.0, 1e-9, -1e9, 0.0],
        });
        req_roundtrip(Request::Stats);
        req_roundtrip(Request::Ping);
        req_roundtrip(Request::Shutdown);
        req_roundtrip(Request::DumpDiagnostics);
    }

    #[test]
    fn responses_roundtrip() {
        resp_roundtrip(Response {
            generation: 3,
            payload: ResponsePayload::Assign { labels: vec![0, 7, 2] },
        });
        resp_roundtrip(Response {
            generation: 1,
            payload: ResponsePayload::Score {
                labels: vec![1, 0],
                dists: vec![0.25, 9.5],
                objective: 9.75,
            },
        });
        resp_roundtrip(Response {
            generation: 9,
            payload: ResponsePayload::Stats { json: "{\"requests\":4}".into() },
        });
        resp_roundtrip(Response {
            generation: 4,
            payload: ResponsePayload::Diagnostics {
                json: "{\"schema\":\"bigmeans.diagnostics.v1\"}".into(),
            },
        });
        resp_roundtrip(Response { generation: 2, payload: ResponsePayload::Pong });
        resp_roundtrip(Response { generation: 2, payload: ResponsePayload::ShuttingDown });
        resp_roundtrip(Response {
            generation: 5,
            payload: ResponsePayload::Error { message: "dims mismatch".into() },
        });
    }

    #[test]
    fn clean_eof_is_a_disconnect_and_torn_frames_are_errors() {
        let mut empty: &[u8] = &[];
        assert!(read_request(&mut empty).unwrap().is_none());
        // A frame whose length promises more bytes than follow.
        let mut torn: &[u8] = &[9, 0, 0, 0, 1, 2];
        assert!(read_request(&mut torn).is_err());
        // EOF inside the length prefix itself.
        let mut torn: &[u8] = &[9, 0];
        assert!(read_request(&mut torn).is_err());
    }

    #[test]
    fn oversized_and_malformed_frames_rejected() {
        let mut huge = Vec::new();
        huge.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        let mut r: &[u8] = &huge;
        assert!(read_request(&mut r).is_err());
        // Shape lies: body length disagrees with rows × n.
        let mut body = vec![OP_ASSIGN];
        body.extend_from_slice(&5u32.to_le_bytes());
        body.extend_from_slice(&4u32.to_le_bytes());
        body.extend_from_slice(&[0u8; 8]); // 2 floats, not 20
        let mut buf = Vec::new();
        write_frame(&mut buf, &body).unwrap();
        let mut r: &[u8] = &buf;
        assert!(read_request(&mut r).is_err());
        // Pointless op carrying points is malformed.
        let mut body = vec![OP_PING];
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&[0u8; 4]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &body).unwrap();
        let mut r: &[u8] = &buf;
        assert!(read_request(&mut r).is_err());
    }
}
