//! The daemon: accept loop, per-connection handlers, live counters.
//!
//! One OS thread per connection reads frames in order; the compute inside
//! each request is sharded across the shared
//! [`ThreadPool`](crate::util::threadpool::ThreadPool) via
//! [`assign_only_pooled`], whose row-carved tiling is bit-identical to the
//! offline `assign_only` pass — so a served label never disagrees with
//! what a batch job would have produced from the same model generation.
//!
//! Shutdown is cooperative and drop-free: the handler that receives the
//! shutdown op answers it first, then raises the stop flag, half-closes
//! every live connection (each blocked reader sees EOF and drains out),
//! and pokes the accept loop awake with a throwaway self-connection.
//! Sockets carry **no read timeouts** — a timeout mid-frame would desync
//! the length-prefixed stream; torn frames already kill exactly one
//! connection.

use std::collections::HashMap;
use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::kernels::assign_only_pooled;
use crate::metrics::Counters;
use crate::obs::{self, Log2Histogram};
use crate::serve::protocol::{read_request, write_response, Request, Response, ResponsePayload};
use crate::serve::registry::{ModelRegistry, ServingModel};
use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};
use crate::util::sync::lock_recover;
use crate::util::threadpool::ThreadPool;

/// Daemon tuning knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Worker threads for sharding one batch; 0 = auto-size to the host.
    pub threads: usize,
    /// Largest accepted `rows` per request; bigger batches get an error
    /// response, not a dropped connection.
    pub max_batch_rows: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { threads: 0, max_batch_rows: 1 << 20 }
    }
}

/// Request operation class for stats/metrics attribution. `Other` covers
/// stats/ping/shutdown so housekeeping traffic never pollutes the data-op
/// latency percentiles.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Op {
    Assign = 0,
    Score = 1,
    Other = 2,
}

/// Per-op counters + latency histogram, mirrored into the process metric
/// registry (the mirror handles are branch-on-relaxed no-ops unless
/// `--metrics-addr`/`--metrics-out` enabled the registry).
struct OpStats {
    requests: AtomicU64,
    rows: AtomicU64,
    errors: AtomicU64,
    hist: Log2Histogram,
    m_requests: obs::Counter,
    m_rows: obs::Counter,
    m_errors: obs::Counter,
    m_hist: obs::Histogram,
}

impl OpStats {
    fn new(op: &'static str) -> OpStats {
        let m = obs::metrics();
        let labels = [("op", op)];
        OpStats {
            requests: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            hist: Log2Histogram::new(),
            m_requests: m.counter(
                "bigmeans_serve_requests_total",
                "Requests answered by the serve daemon (including error responses)",
                &labels,
            ),
            m_rows: m.counter(
                "bigmeans_serve_rows_total",
                "Data rows processed by the serve daemon",
                &labels,
            ),
            m_errors: m.counter(
                "bigmeans_serve_errors_total",
                "Error responses sent by the serve daemon",
                &labels,
            ),
            m_hist: m.histogram(
                "bigmeans_serve_request_duration_seconds",
                "Server-side request handling latency",
                &labels,
            ),
        }
    }
}

/// Live request counters, shared by every connection handler.
pub struct ServeStats {
    started: Instant,
    requests: AtomicU64,
    data_requests: AtomicU64,
    rows: AtomicU64,
    errors: AtomicU64,
    /// Indexed by `Op as usize`.
    ops: [OpStats; 3],
    agg: Mutex<Counters>,
    m_distance_evals: obs::Counter,
    m_pruned_evals: obs::Counter,
}

impl ServeStats {
    fn new() -> ServeStats {
        let m = obs::metrics();
        let eng = [("engine", "serve"), ("isa", crate::kernels::active_isa().name())];
        ServeStats {
            started: Instant::now(),
            requests: AtomicU64::new(0),
            data_requests: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            ops: [OpStats::new("assign"), OpStats::new("score"), OpStats::new("other")],
            agg: Mutex::new(Counters::new()),
            m_distance_evals: m.counter(
                "bigmeans_distance_evals_total",
                "Exact point-to-centroid distance evaluations (paper n_d)",
                &eng,
            ),
            m_pruned_evals: m.counter(
                "bigmeans_pruned_evals_total",
                "Distance evaluations avoided by bound-based pruning",
                &eng,
            ),
        }
    }

    fn record(
        &self,
        op: Op,
        elapsed: Duration,
        batch_rows: Option<usize>,
        counters: Option<&Counters>,
    ) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let os = &self.ops[op as usize];
        os.requests.fetch_add(1, Ordering::Relaxed);
        os.m_requests.inc();
        if let Some(rows) = batch_rows {
            self.data_requests.fetch_add(1, Ordering::Relaxed);
            self.rows.fetch_add(rows as u64, Ordering::Relaxed);
            os.rows.fetch_add(rows as u64, Ordering::Relaxed);
            os.m_rows.add(rows as u64);
        }
        if let Some(c) = counters {
            lock_recover(&self.agg).merge(c);
            self.m_distance_evals.add(c.distance_evals);
            self.m_pruned_evals.add(c.pruned_evals);
        }
        os.hist.record(elapsed);
        os.m_hist.observe(elapsed);
    }

    /// An answered error response counts as a request too (it occupied
    /// the handler and the client got a reply), attributed to its op.
    fn record_error(&self, op: Op, elapsed: Duration) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.errors.fetch_add(1, Ordering::Relaxed);
        let os = &self.ops[op as usize];
        os.requests.fetch_add(1, Ordering::Relaxed);
        os.errors.fetch_add(1, Ordering::Relaxed);
        os.m_requests.inc();
        os.m_errors.inc();
        os.hist.record(elapsed);
        os.m_hist.observe(elapsed);
    }

    /// Requests answered so far (all ops).
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Error responses sent so far.
    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    fn op_json(&self, op: Op) -> Json {
        let os = &self.ops[op as usize];
        json::obj(vec![
            ("requests", json::num(os.requests.load(Ordering::Relaxed) as f64)),
            ("rows", json::num(os.rows.load(Ordering::Relaxed) as f64)),
            ("errors", json::num(os.errors.load(Ordering::Relaxed) as f64)),
            ("p50_ms", json::num(os.hist.percentile_secs(0.50) * 1e3)),
            ("p95_ms", json::num(os.hist.percentile_secs(0.95) * 1e3)),
            ("p99_ms", json::num(os.hist.percentile_secs(0.99) * 1e3)),
        ])
    }

    /// The `--json` / stats-op document: throughput, batch shape, latency
    /// percentiles, swap generation, and the kernel work counters. The
    /// top-level percentiles cover the data ops only (assign + score
    /// merged); housekeeping ops report under `ops.other`.
    pub fn to_json(&self, registry: &ModelRegistry) -> Json {
        let requests = self.requests.load(Ordering::Relaxed);
        let data_requests = self.data_requests.load(Ordering::Relaxed);
        let rows = self.rows.load(Ordering::Relaxed);
        let errors = self.errors.load(Ordering::Relaxed);
        let uptime = self.started.elapsed().as_secs_f64().max(1e-9);
        let mean_batch =
            if data_requests == 0 { 0.0 } else { rows as f64 / data_requests as f64 };
        let (distance_evals, pruned_evals, pruned_blocks, hybrid_switches) = {
            let agg = lock_recover(&self.agg);
            (agg.distance_evals, agg.pruned_evals, agg.pruned_blocks, agg.hybrid_switches)
        };
        let data_hists =
            [&self.ops[Op::Assign as usize].hist, &self.ops[Op::Score as usize].hist];
        json::obj(vec![
            ("requests", json::num(requests as f64)),
            ("rows", json::num(rows as f64)),
            ("errors", json::num(errors as f64)),
            ("isa", json::s(crate::kernels::active_isa().name())),
            ("qps", json::num(requests as f64 / uptime)),
            ("mean_batch_rows", json::num(mean_batch)),
            (
                "p50_ms",
                json::num(Log2Histogram::percentile_secs_merged(&data_hists, 0.50) * 1e3),
            ),
            (
                "p95_ms",
                json::num(Log2Histogram::percentile_secs_merged(&data_hists, 0.95) * 1e3),
            ),
            (
                "p99_ms",
                json::num(Log2Histogram::percentile_secs_merged(&data_hists, 0.99) * 1e3),
            ),
            (
                "ops",
                json::obj(vec![
                    ("assign", self.op_json(Op::Assign)),
                    ("score", self.op_json(Op::Score)),
                    ("other", self.op_json(Op::Other)),
                ]),
            ),
            ("generation", json::num(registry.generation() as f64)),
            ("swaps", json::num(registry.swaps() as f64)),
            // Learned hybrid switch threshold carried by the served
            // model's meta (written by `--mode tune --save-model`);
            // null for models trained without one.
            (
                "hybrid_threshold",
                registry
                    .current()
                    .artifact
                    .meta
                    .get("hybrid_threshold")
                    .and_then(Json::as_f64)
                    .map(json::num)
                    .unwrap_or(Json::Null),
            ),
            ("distance_evals", json::num(distance_evals as f64)),
            ("pruned_evals", json::num(pruned_evals as f64)),
            ("pruned_blocks", json::num(pruned_blocks as f64)),
            ("hybrid_switches", json::num(hybrid_switches as f64)),
            ("uptime_secs", json::num(self.started.elapsed().as_secs_f64())),
        ])
    }
}

/// Everything a connection handler needs, behind one `Arc`.
struct Shared {
    registry: Arc<ModelRegistry>,
    stats: Arc<ServeStats>,
    pool: ThreadPool,
    stop: Arc<AtomicBool>,
    conns: Mutex<HashMap<u64, TcpStream>>,
    local_addr: SocketAddr,
    max_batch_rows: usize,
}

/// The serving daemon. `bind` then `run`; `run` returns after a client
/// sends the shutdown op (or [`Server::shutdown_handle`] is raised and
/// the loop is woken by a connection).
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral test port) and prepare
    /// the worker pool.
    pub fn bind(addr: &str, registry: Arc<ModelRegistry>, opts: ServeOptions) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind serve addr {addr}"))?;
        let local_addr = listener.local_addr().context("serve local_addr")?;
        let pool = if opts.threads == 0 {
            ThreadPool::with_default_size()
        } else {
            ThreadPool::new(opts.threads)
        };
        let shared = Arc::new(Shared {
            registry,
            stats: Arc::new(ServeStats::new()),
            pool,
            stop: Arc::new(AtomicBool::new(false)),
            conns: Mutex::new(HashMap::new()),
            local_addr,
            max_batch_rows: opts.max_batch_rows.max(1),
        });
        Ok(Server { listener, shared })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.local_addr
    }

    /// Live counters, shared with every handler.
    pub fn stats(&self) -> Arc<ServeStats> {
        Arc::clone(&self.shared.stats)
    }

    /// Cooperative stop flag. Raising it externally (e.g. from a signal
    /// handler) stops the accept loop at its next wake-up; the in-band
    /// shutdown op raises it *and* wakes everything immediately.
    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shared.stop)
    }

    /// Accept connections until shutdown; joins every handler before
    /// returning, so no response is ever abandoned mid-write.
    pub fn run(&self) -> Result<()> {
        let mut handles = Vec::new();
        let mut next_id = 0u64;
        loop {
            if self.shared.stop.load(Ordering::SeqCst) {
                break;
            }
            let (stream, _) = match self.listener.accept() {
                Ok(pair) => pair,
                Err(e) => {
                    if self.shared.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    crate::log_warn!("serve", "accept failed: {e}");
                    continue;
                }
            };
            if self.shared.stop.load(Ordering::SeqCst) {
                break; // the wake-up self-connection, or a racer
            }
            stream.set_nodelay(true).ok();
            next_id += 1;
            let id = next_id;
            if let Ok(clone) = stream.try_clone() {
                lock_recover(&self.shared.conns).insert(id, clone);
            }
            let shared = Arc::clone(&self.shared);
            let handle = std::thread::Builder::new()
                .name(format!("bigmeans-serve-conn-{id}"))
                .spawn(move || {
                    handle_connection(stream, id, &shared);
                    lock_recover(&shared.conns).remove(&id);
                })
                .context("spawn connection handler")?;
            handles.push(handle);
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Answer a batched assign/score request from one model snapshot.
fn answer_batch(
    shared: &Shared,
    model: &ServingModel,
    rows: usize,
    n: usize,
    points: &[f32],
    score: bool,
) -> (ResponsePayload, usize, Counters) {
    let (k, dims) = (model.artifact.k, model.artifact.n);
    debug_assert_eq!(n, dims);
    let mut labels = vec![0u32; rows];
    let mut mins = vec![0f32; rows];
    let mut counters = Counters::new();
    assign_only_pooled(
        &shared.pool,
        points,
        &model.artifact.centroids,
        &model.c_sq,
        rows,
        dims,
        k,
        &mut labels,
        &mut mins,
        &mut counters,
    );
    let payload = if score {
        let objective: f64 = mins.iter().map(|&d| f64::from(d)).sum();
        ResponsePayload::Score { labels, dists: mins, objective }
    } else {
        ResponsePayload::Assign { labels }
    };
    (payload, rows, counters)
}

/// Serve one connection until disconnect, torn frame, or shutdown.
fn handle_connection(mut stream: TcpStream, _id: u64, shared: &Shared) {
    loop {
        let req = match read_request(&mut stream) {
            Ok(Some(req)) => req,
            // Clean disconnect, torn frame, or our own half-close during
            // shutdown — all end exactly this connection.
            Ok(None) | Err(_) => return,
        };
        let start = Instant::now();
        let (rows_n, score) = match &req {
            Request::Assign { rows, n, .. } => (Some((*rows, *n)), false),
            Request::Score { rows, n, .. } => (Some((*rows, *n)), true),
            _ => (None, false),
        };
        let response = match &req {
            Request::Assign { points, .. } | Request::Score { points, .. } => {
                let (rows, n) = rows_n.unwrap();
                let (op, op_name) =
                    if score { (Op::Score, "score") } else { (Op::Assign, "assign") };
                let _span = obs::tracer().span("serve.request", op_name);
                let model = shared.registry.current();
                if n != model.artifact.n {
                    shared.stats.record_error(op, start.elapsed());
                    Response {
                        generation: model.generation,
                        payload: ResponsePayload::Error {
                            message: format!(
                                "dims mismatch: request has {n}, model serves {}",
                                model.artifact.n
                            ),
                        },
                    }
                } else if rows > shared.max_batch_rows {
                    shared.stats.record_error(op, start.elapsed());
                    Response {
                        generation: model.generation,
                        payload: ResponsePayload::Error {
                            message: format!(
                                "batch of {rows} rows exceeds cap {}",
                                shared.max_batch_rows
                            ),
                        },
                    }
                } else {
                    let (payload, rows, counters) =
                        answer_batch(shared, &model, rows, n, points, score);
                    shared.stats.record(op, start.elapsed(), Some(rows), Some(&counters));
                    Response { generation: model.generation, payload }
                }
            }
            Request::Stats => {
                let json = shared.stats.to_json(&shared.registry).to_string();
                shared.stats.record(Op::Other, start.elapsed(), None, None);
                Response {
                    generation: shared.registry.generation(),
                    payload: ResponsePayload::Stats { json },
                }
            }
            Request::DumpDiagnostics => {
                let json = obs::recorder()
                    .dump_json("serve-request", None)
                    .to_string();
                shared.stats.record(Op::Other, start.elapsed(), None, None);
                Response {
                    generation: shared.registry.generation(),
                    payload: ResponsePayload::Diagnostics { json },
                }
            }
            Request::Ping => {
                shared.stats.record(Op::Other, start.elapsed(), None, None);
                Response {
                    generation: shared.registry.generation(),
                    payload: ResponsePayload::Pong,
                }
            }
            Request::Shutdown => {
                shared.stats.record(Op::Other, start.elapsed(), None, None);
                Response {
                    generation: shared.registry.generation(),
                    payload: ResponsePayload::ShuttingDown,
                }
            }
        };
        if write_response(&mut stream, &response).is_err() {
            return; // peer vanished mid-response; nothing to salvage
        }
        if matches!(req, Request::Shutdown) {
            initiate_shutdown(shared);
            return;
        }
    }
}

/// Raise the stop flag, half-close every live connection so blocked
/// readers drain, and poke the accept loop awake.
fn initiate_shutdown(shared: &Shared) {
    shared.stop.store(true, Ordering::SeqCst);
    for conn in lock_recover(&shared.conns).values() {
        let _ = conn.shutdown(Shutdown::Both);
    }
    // `accept` has no timeout; a throwaway self-connection wakes it so it
    // can observe the flag. Failure is fine — the next real connection
    // (or an OS-level close) unblocks it the same way.
    let _: io::Result<TcpStream> = TcpStream::connect(shared.local_addr);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::assign_only;
    use crate::serve::artifact::ModelArtifact;
    use crate::serve::protocol::Client;
    use crate::util::rng::Rng;

    fn boot(k: usize, n: usize, seed: u64) -> (Arc<ModelRegistry>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let centroids: Vec<f32> =
            (0..k * n).map(|_| (rng.f64() * 10.0 - 5.0) as f32).collect();
        let artifact =
            ModelArtifact::new(k, n, 1, 123.0, Json::Null, centroids.clone()).unwrap();
        (ModelRegistry::new(artifact), centroids)
    }

    #[test]
    fn daemon_answers_bit_identically_then_shuts_down() {
        let (k, n, rows) = (7, 3, 301);
        let (registry, centroids) = boot(k, n, 11);
        let server = Server::bind(
            "127.0.0.1:0",
            Arc::clone(&registry),
            ServeOptions { threads: 2, max_batch_rows: 4096 },
        )
        .unwrap();
        let addr = server.local_addr().to_string();
        let runner = std::thread::spawn(move || server.run().unwrap());

        let mut rng = Rng::new(99);
        let points: Vec<f32> =
            (0..rows * n).map(|_| (rng.f64() * 8.0 - 4.0) as f32).collect();
        let mut counters = Counters::new();
        let (want_labels, want_mins) =
            assign_only(&points, &centroids, rows, n, k, &mut counters);

        let mut client = Client::connect(&addr).unwrap();
        let (generation, labels) = client.assign(&points, rows, n).unwrap();
        assert_eq!(generation, 1);
        assert_eq!(labels, want_labels);
        let (_, labels2, dists, objective) = client.score(&points, rows, n).unwrap();
        assert_eq!(labels2, want_labels);
        let same = dists.iter().zip(&want_mins).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "served dists must be bit-identical to assign_only mins");
        let want_obj: f64 = want_mins.iter().map(|&d| f64::from(d)).sum();
        assert_eq!(objective.to_bits(), want_obj.to_bits());

        // Malformed batches get error responses on a live connection.
        assert!(client.assign(&points[..rows * 2], rows, 2).is_err());
        let huge = vec![0.0f32; 5000 * n];
        assert!(client.assign(&huge, 5000, n).is_err());
        let (_, json) = client.stats().unwrap();
        let doc = Json::parse(&json).unwrap();
        assert!(doc.get("requests").and_then(|v| v.as_f64()).unwrap() >= 3.0);
        assert_eq!(doc.get("errors").and_then(|v| v.as_f64()).unwrap(), 2.0);
        // Per-op split: both malformed batches were assign ops, and the
        // housekeeping ops never pollute the data-op histograms.
        let ops = doc.get("ops").expect("stats json has per-op block");
        let op = |name: &str, key: &str| {
            ops.get(name).and_then(|o| o.get(key)).and_then(|v| v.as_f64()).unwrap()
        };
        assert_eq!(op("assign", "errors"), 2.0);
        assert_eq!(op("assign", "requests"), 3.0);
        assert_eq!(op("score", "requests"), 1.0);
        assert_eq!(op("other", "errors"), 0.0);

        client.shutdown().unwrap();
        runner.join().unwrap();
    }
}
