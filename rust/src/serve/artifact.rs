//! The `.bmm` model artifact — the versioned on-disk form of a trained
//! model, CRC-protected like `.bmx`.
//!
//! ## Layout (v1, all integers little-endian)
//!
//! ```text
//! offset  size  field
//! 0       4     magic "BMM1"
//! 4       4     k (u32, > 0)
//! 8       4     n (u32, > 0)      — dims
//! 12      8     generation (u64)  — publisher's ordinal (1 = first)
//! 20      8     objective (f64 bits) — training SSE of these centroids
//! 28      4     meta_len (u32)    — bytes of the metadata JSON
//! 32      4     meta_crc (u32)    — CRC-32 of the metadata bytes
//! 36      4     payload_crc (u32) — CRC-32 of the centroid bytes
//! 40      4     header_crc (u32)  — CRC-32 of bytes 0..40
//! 44      4     reserved (zero)
//! 48      —     metadata JSON (meta_len bytes, provenance: dataset,
//!               mode, seed, …)
//! 48+meta —     centroids: k × n f32 LE (the payload)
//! ```
//!
//! Publishing is atomic (`.tmp` + rename), so a watching daemon never
//! observes a half-written file as valid: a torn read fails the length or
//! CRC checks and is retried on the next poll. The dtype is fixed at f32
//! — the serving arithmetic contract (bit-identical to `assign_only`)
//! only holds in the f32 domain.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::util::hash::crc32;
use crate::util::json::Json;
use crate::{anyhow, bail};

/// Artifact magic: "BM" + model + format version 1.
pub const BMM_MAGIC: [u8; 4] = *b"BMM1";

/// Fixed header bytes before the metadata JSON.
pub const BMM_HEADER_LEN: usize = 48;

/// A trained model as stored in / loaded from a `.bmm` file.
#[derive(Clone, Debug)]
pub struct ModelArtifact {
    /// Number of centroids.
    pub k: usize,
    /// Dimensions per centroid.
    pub n: usize,
    /// Publisher's generation ordinal (1 = first publish). Distinct from
    /// the registry's swap generation, which counts what a *daemon* has
    /// actually swapped in.
    pub generation: u64,
    /// Training objective (SSE) of these centroids.
    pub objective: f64,
    /// Provenance metadata (dataset, mode, seed, …) — free-form JSON.
    pub meta: Json,
    /// Row-major `k × n` centroid matrix.
    pub centroids: Vec<f32>,
}

impl ModelArtifact {
    /// Build an artifact, checking the centroid shape.
    pub fn new(
        k: usize,
        n: usize,
        generation: u64,
        objective: f64,
        meta: Json,
        centroids: Vec<f32>,
    ) -> Result<ModelArtifact> {
        if k == 0 || n == 0 {
            bail!("model artifact needs k > 0 and n > 0 (got k={k}, n={n})");
        }
        if centroids.len() != k * n {
            bail!(
                "model artifact centroid shape mismatch: {} values for k={k} × n={n}",
                centroids.len()
            );
        }
        Ok(ModelArtifact { k, n, generation, objective, meta, centroids })
    }

    /// CRC-32 of the centroid payload bytes — the cheap content identity
    /// the watcher uses to skip republishing an identical model.
    pub fn payload_crc(&self) -> u32 {
        crc32(&self.payload_bytes())
    }

    fn payload_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.centroids.len() * 4);
        for v in &self.centroids {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Serialize to the v1 byte layout.
    pub fn encode(&self) -> Vec<u8> {
        let meta_bytes = self.meta.to_string().into_bytes();
        let payload = self.payload_bytes();
        let mut hdr = [0u8; BMM_HEADER_LEN];
        hdr[0..4].copy_from_slice(&BMM_MAGIC);
        hdr[4..8].copy_from_slice(&(self.k as u32).to_le_bytes());
        hdr[8..12].copy_from_slice(&(self.n as u32).to_le_bytes());
        hdr[12..20].copy_from_slice(&self.generation.to_le_bytes());
        hdr[20..28].copy_from_slice(&self.objective.to_bits().to_le_bytes());
        hdr[28..32].copy_from_slice(&(meta_bytes.len() as u32).to_le_bytes());
        hdr[32..36].copy_from_slice(&crc32(&meta_bytes).to_le_bytes());
        hdr[36..40].copy_from_slice(&crc32(&payload).to_le_bytes());
        let header_crc = crc32(&hdr[0..40]);
        hdr[40..44].copy_from_slice(&header_crc.to_le_bytes());
        let mut out = Vec::with_capacity(BMM_HEADER_LEN + meta_bytes.len() + payload.len());
        out.extend_from_slice(&hdr);
        out.extend_from_slice(&meta_bytes);
        out.extend_from_slice(&payload);
        out
    }

    /// Write atomically (`.tmp` + rename): a concurrent reader sees either
    /// the old complete file or the new complete file, never a torn one.
    pub fn save(&self, path: &Path) -> Result<()> {
        let bytes = self.encode();
        let tmp = {
            let mut os = path.as_os_str().to_os_string();
            os.push(".tmp");
            PathBuf::from(os)
        };
        let write = || -> std::io::Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.flush()?;
            std::fs::rename(&tmp, path)
        };
        if let Err(e) = write() {
            let _ = std::fs::remove_file(&tmp);
            return Err(anyhow!("save model artifact {}: {e}", path.display()));
        }
        Ok(())
    }

    /// Decode from bytes, validating magic, header CRC, geometry, exact
    /// length, metadata CRC, and payload CRC — every failure is a named
    /// error so a daemon can log *why* a publish was rejected.
    pub fn decode(bytes: &[u8], label: &str) -> Result<ModelArtifact> {
        if bytes.len() < BMM_HEADER_LEN {
            bail!(
                "{label}: truncated model artifact ({} bytes, header needs {BMM_HEADER_LEN})",
                bytes.len()
            );
        }
        if bytes[0..4] != BMM_MAGIC {
            bail!("{label}: not a .bmm model artifact (bad magic)");
        }
        let stored_header_crc = u32::from_le_bytes(bytes[40..44].try_into().unwrap());
        let computed = crc32(&bytes[0..40]);
        if computed != stored_header_crc {
            bail!(
                "{label}: model artifact header checksum mismatch (expected \
                 {stored_header_crc:#010x}, computed {computed:#010x})"
            );
        }
        let k = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        let n = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let generation = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
        let objective =
            f64::from_bits(u64::from_le_bytes(bytes[20..28].try_into().unwrap()));
        let meta_len = u32::from_le_bytes(bytes[28..32].try_into().unwrap()) as usize;
        let meta_crc = u32::from_le_bytes(bytes[32..36].try_into().unwrap());
        let payload_crc = u32::from_le_bytes(bytes[36..40].try_into().unwrap());
        if k == 0 || n == 0 {
            bail!("{label}: model artifact has k = {k}, n = {n} (both must be > 0)");
        }
        let payload_len = k
            .checked_mul(n)
            .and_then(|v| v.checked_mul(4))
            .ok_or_else(|| anyhow!("{label}: model artifact geometry overflows"))?;
        let want_len = BMM_HEADER_LEN + meta_len + payload_len;
        if bytes.len() != want_len {
            bail!(
                "{label}: truncated model artifact ({} bytes, k={k} × n={n} with \
                 {meta_len} metadata bytes needs exactly {want_len})",
                bytes.len()
            );
        }
        let meta_bytes = &bytes[BMM_HEADER_LEN..BMM_HEADER_LEN + meta_len];
        let computed = crc32(meta_bytes);
        if computed != meta_crc {
            bail!(
                "{label}: model artifact metadata checksum mismatch (expected \
                 {meta_crc:#010x}, computed {computed:#010x})"
            );
        }
        let payload = &bytes[BMM_HEADER_LEN + meta_len..];
        let computed = crc32(payload);
        if computed != payload_crc {
            bail!(
                "{label}: model artifact payload checksum mismatch (expected \
                 {payload_crc:#010x}, computed {computed:#010x})"
            );
        }
        let meta = if meta_bytes.is_empty() {
            Json::Null
        } else {
            let text = std::str::from_utf8(meta_bytes)
                .map_err(|_| anyhow!("{label}: model artifact metadata is not UTF-8"))?;
            Json::parse(text)
                .map_err(|e| anyhow!("{label}: model artifact metadata: {e}"))?
        };
        let centroids: Vec<f32> = payload
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        Ok(ModelArtifact { k, n, generation, objective, meta, centroids })
    }

    /// Load and validate a `.bmm` file.
    pub fn load(path: &Path) -> Result<ModelArtifact> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read model artifact {}", path.display()))?;
        Self::decode(&bytes, &path.display().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, obj, s};

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("bigmeans_serve_artifact_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{name}", std::process::id()))
    }

    fn sample() -> ModelArtifact {
        ModelArtifact::new(
            3,
            2,
            7,
            123.456,
            obj(vec![("dataset", s("toy")), ("seed", num(42.0))]),
            vec![0.0, 1.0, -2.5, 3.25, 1e-8, -1e8],
        )
        .unwrap()
    }

    #[test]
    fn roundtrips_through_disk() {
        let p = tmp("round.bmm");
        let a = sample();
        a.save(&p).unwrap();
        let b = ModelArtifact::load(&p).unwrap();
        assert_eq!(b.k, 3);
        assert_eq!(b.n, 2);
        assert_eq!(b.generation, 7);
        assert_eq!(b.objective.to_bits(), 123.456f64.to_bits());
        assert_eq!(b.meta.get("dataset").unwrap().as_str(), Some("toy"));
        let same = a
            .centroids
            .iter()
            .zip(&b.centroids)
            .all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(same, "centroids must roundtrip bit-exactly");
        assert_eq!(a.payload_crc(), b.payload_crc());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn corruption_is_a_named_error() {
        let a = sample();
        let good = a.encode();
        // Payload byte flip → payload checksum error.
        let mut bytes = good.clone();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x10;
        let err = ModelArtifact::decode(&bytes, "t").unwrap_err().to_string();
        assert!(err.contains("payload checksum"), "{err}");
        // Metadata byte flip → metadata checksum error.
        let mut bytes = good.clone();
        bytes[BMM_HEADER_LEN] ^= 0x01;
        let err = ModelArtifact::decode(&bytes, "t").unwrap_err().to_string();
        assert!(err.contains("metadata checksum"), "{err}");
        // Header byte flip → header checksum error.
        let mut bytes = good.clone();
        bytes[5] ^= 0x01;
        let err = ModelArtifact::decode(&bytes, "t").unwrap_err().to_string();
        assert!(err.contains("header checksum"), "{err}");
        // Bad magic is named before any CRC.
        let mut bytes = good.clone();
        bytes[0] = b'X';
        let err = ModelArtifact::decode(&bytes, "t").unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
        // Truncation → named truncation error (a torn concurrent read).
        let err = ModelArtifact::decode(&good[..good.len() - 3], "t")
            .unwrap_err()
            .to_string();
        assert!(err.contains("truncated"), "{err}");
        let err = ModelArtifact::decode(&good[..10], "t").unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn zero_geometry_rejected_at_build_and_decode() {
        assert!(ModelArtifact::new(0, 2, 1, 0.0, Json::Null, vec![]).is_err());
        assert!(ModelArtifact::new(2, 2, 1, 0.0, Json::Null, vec![0.0; 3]).is_err());
    }

    #[test]
    fn empty_meta_roundtrips_as_null() {
        let p = tmp("nometa.bmm");
        let a = ModelArtifact::new(1, 1, 1, 0.0, Json::Null, vec![2.0]).unwrap();
        // Json::Null serializes to "null" (non-empty), so force the empty
        // case through encode/decode of a fresh artifact with Null meta.
        a.save(&p).unwrap();
        let b = ModelArtifact::load(&p).unwrap();
        assert_eq!(b.meta, Json::Null);
        let _ = std::fs::remove_file(&p);
    }
}
