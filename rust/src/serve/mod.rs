//! Serve mode: a long-running, hot-swappable clustering daemon.
//!
//! The paper trains an MSSC model; this module *serves* it. Four layers,
//! all `std`-only:
//!
//! * [`artifact`] — the versioned `.bmm` model artifact (centroids +
//!   geometry + objective + provenance metadata, CRC-protected like
//!   `.bmx`): what training writes and the daemon loads;
//! * [`registry`] — [`ModelRegistry`], an `ArcSwap`-style atomic
//!   hot-swap point (`RwLock<Arc<ServingModel>>` + generation counter)
//!   with a file watcher so a concurrently running `--mode stream` job
//!   can publish refreshed centroids mid-flight;
//! * [`protocol`] — the length-prefixed TCP wire format and the
//!   [`Client`] used by the CLI, the bench suite, and the tests;
//! * [`server`] — the accept loop: batched assign/score requests sharded
//!   across the [`crate::util::threadpool::ThreadPool`] via
//!   [`crate::kernels::assign_only_pooled`], so served labels are
//!   **bit-identical** to the offline `assign_only`/`canonical_final_pass`
//!   output for whichever model generation answered.

pub mod artifact;
pub mod protocol;
pub mod registry;
pub mod server;

pub use artifact::{ModelArtifact, BMM_HEADER_LEN, BMM_MAGIC};
pub use protocol::{Client, Request, Response, ResponsePayload};
pub use registry::{spawn_watcher, ModelRegistry, ServingModel};
pub use server::{ServeOptions, ServeStats, Server};
