//! Engine equivalence: the Hamerly-bounded, Elkan (u16-quantised
//! bounds), and rescan-adaptive hybrid kernel engines must be *exact*
//! drop-ins for the blocked-panel engine — identical labels, counts, and
//! centroid trajectories, objectives within fp slack — while performing
//! strictly fewer distance evaluations on clustered data. All engines
//! share the decomposition arithmetic, so the comparisons here can be
//! tight. The dispatched-SIMD sweep at the bottom additionally gates the
//! bit-identity of every runtime ISA backend against scalar.

use bigmeans::coordinator::config::{
    BigMeansConfig, KernelEngineKind, ParallelMode, StopCondition,
};
use bigmeans::data::bmx::{save_bmx, BmxSource};
use bigmeans::data::synth::Synth;
use bigmeans::kernels::engine::{
    BoundedEngine, ElkanEngine, HybridEngine, KernelEngine, LloydState, PanelEngine,
};
use bigmeans::kernels::{self, detect_isa, set_isa, DistanceIsa, LloydParams};
use bigmeans::metrics::Counters;
use bigmeans::util::prop::{check, ClusterProblem, ClusterProblemGen};
use bigmeans::util::rng::Rng;
use bigmeans::util::threadpool::ThreadPool;
use bigmeans::{BigMeans, Dataset};

fn seed_centroids(p: &ClusterProblem, rng: &mut Rng) -> Vec<f32> {
    let idx = rng.sample_indices(p.m, p.k);
    let mut c = Vec::with_capacity(p.k * p.n);
    for &i in &idx {
        c.extend_from_slice(&p.points[i * p.n..(i + 1) * p.n]);
    }
    c
}

#[test]
fn prop_pruning_engines_lloyd_identical_to_panel_serial() {
    // Full Lloyd runs across random shapes/seeds: every pruning engine
    // must reproduce the panel engine's counts, iteration count, centroid
    // trajectory, and (within 1e-6 relative) objective.
    let bounded = BoundedEngine::default();
    let elkan = ElkanEngine::default();
    let hybrid = HybridEngine::default();
    let engines: [(&str, &dyn KernelEngine); 3] =
        [("bounded", &bounded), ("elkan", &elkan), ("hybrid", &hybrid)];
    for (name, engine) in engines {
        check(41, 60, &ClusterProblemGen::default(), |p| {
            let mut rng = Rng::new(101);
            let c0 = seed_centroids(p, &mut rng);
            let params = LloydParams::default();
            let mut ca = Counters::new();
            let mut cb = Counters::new();
            let a = kernels::lloyd_with_engine(
                &p.points, &c0, p.m, p.n, p.k, params, None, &PanelEngine, &mut ca,
            );
            let b = kernels::lloyd_with_engine(
                &p.points, &c0, p.m, p.n, p.k, params, None, engine, &mut cb,
            );
            let ok = a.counts == b.counts
                && a.iters == b.iters
                && a.centroids == b.centroids
                && (a.objective - b.objective).abs() <= 1e-6 * a.objective.abs() + 1e-9;
            if !ok {
                eprintln!("engine {name} diverged on m={} n={} k={}", p.m, p.n, p.k);
            }
            ok
        });
    }
}

#[test]
fn prop_bounded_parallel_step_identical_to_serial() {
    // Pool-parallel bounded assignment (per-worker bound slices) must match
    // the serial bounded path point-for-point on random, non-block-aligned
    // shapes. Both paths are driven along the same centroid trajectory so
    // the comparison is exact (the parallel path merges f64 sums in worker
    // order, which may differ in the last bits — kept out of the
    // trajectory on purpose, compared with slack below).
    let gen = ClusterProblemGen {
        m_range: (1, 3000), // crosses the 2·BLOCK_ROWS parallel threshold
        n_range: (1, 10),
        k_max: 6,
        coord_range: (-60.0, 60.0),
    };
    let pool = ThreadPool::new(3);
    check(42, 30, &gen, |p| {
        let mut rng = Rng::new(103);
        let mut c = seed_centroids(p, &mut rng);
        let mut old = vec![0f32; p.k * p.n];
        let mut st_s = LloydState::new(p.m);
        let mut st_p = LloydState::new(p.m);
        let mut cnt_s = Counters::new();
        let mut cnt_p = Counters::new();
        let engine = BoundedEngine::default();
        for _ in 0..4 {
            let a = engine.assign_step(&p.points, &c, p.m, p.n, p.k, &mut st_s, &mut cnt_s);
            let b = engine.assign_step_parallel(
                &pool, &p.points, &c, p.m, p.n, p.k, &mut st_p, &mut cnt_p,
            );
            if a.labels != b.labels
                || a.mins != b.mins
                || a.counts != b.counts
                || (a.objective - b.objective).abs() > 1e-6 * a.objective.abs() + 1e-9
            {
                return false;
            }
            old.copy_from_slice(&c);
            kernels::update_centroids(&a.sums, &a.counts, &mut c, p.k, p.n);
            st_s.apply_update(&old, &c, p.k, p.n);
            st_p.apply_update(&old, &c, p.k, p.n);
        }
        cnt_s.distance_evals == cnt_p.distance_evals && cnt_s.pruned_evals == cnt_p.pruned_evals
    });
}

#[test]
fn prop_bounded_parallel_lloyd_matches_quality() {
    // End-to-end pool-parallel bounded Lloyd: counts and objective agree
    // with the serial panel run within fp merge-order slack.
    let gen = ClusterProblemGen {
        m_range: (600, 2500),
        n_range: (1, 8),
        k_max: 5,
        coord_range: (-60.0, 60.0),
    };
    let pool = ThreadPool::new(3);
    check(44, 20, &gen, |p| {
        let mut rng = Rng::new(109);
        let c0 = seed_centroids(p, &mut rng);
        let params = LloydParams { tol: 1e-4, max_iters: 20 };
        let mut ca = Counters::new();
        let mut cb = Counters::new();
        let panel = kernels::lloyd_with_engine(
            &p.points, &c0, p.m, p.n, p.k, params, None, &PanelEngine, &mut ca,
        );
        let par = kernels::lloyd_with_engine(
            &p.points,
            &c0,
            p.m,
            p.n,
            p.k,
            params,
            Some(&pool),
            &BoundedEngine::default(),
            &mut cb,
        );
        panel.counts == par.counts
            && (panel.objective - par.objective).abs()
                <= 1e-6 * panel.objective.abs() + 1e-9
    });
}

#[test]
fn prop_pruning_engines_step_labels_identical_each_iteration() {
    // Step-level check: labels and mins agree with the panel engine at
    // every single iteration, not just at convergence — for both pruning
    // engines.
    let bounded = BoundedEngine::default();
    let elkan = ElkanEngine::default();
    let hybrid = HybridEngine::default();
    let engines: [&dyn KernelEngine; 3] = [&bounded, &elkan, &hybrid];
    for engine in engines {
        check(43, 40, &ClusterProblemGen::default(), |p| {
            let mut rng = Rng::new(107);
            let c0 = seed_centroids(p, &mut rng);
            let mut c_a = c0.clone();
            let mut c_b = c0;
            let mut st_a = LloydState::new(p.m);
            let mut st_b = LloydState::new(p.m);
            let mut cnt_a = Counters::new();
            let mut cnt_b = Counters::new();
            let mut old = vec![0f32; p.k * p.n];
            let panel = PanelEngine;
            for _ in 0..5 {
                let a =
                    panel.assign_step(&p.points, &c_a, p.m, p.n, p.k, &mut st_a, &mut cnt_a);
                let b =
                    engine.assign_step(&p.points, &c_b, p.m, p.n, p.k, &mut st_b, &mut cnt_b);
                if a.labels != b.labels || a.counts != b.counts || a.mins != b.mins {
                    return false;
                }
                old.copy_from_slice(&c_a);
                kernels::update_centroids(&a.sums, &a.counts, &mut c_a, p.k, p.n);
                st_a.apply_update(&old, &c_a, p.k, p.n);
                old.copy_from_slice(&c_b);
                kernels::update_centroids(&b.sums, &b.counts, &mut c_b, p.k, p.n);
                st_b.apply_update(&old, &c_b, p.k, p.n);
                if c_a != c_b {
                    return false;
                }
            }
            true
        });
    }
}

#[test]
fn prop_elkan_parallel_step_identical_to_serial() {
    // Pool-parallel Elkan assignment (per-worker bound slices, including
    // the rows·k lower-bound matrix) must match the serial Elkan path
    // point-for-point on random, non-block-aligned shapes.
    let gen = ClusterProblemGen {
        m_range: (1, 3000), // crosses the 2·BLOCK_ROWS parallel threshold
        n_range: (1, 10),
        k_max: 6,
        coord_range: (-60.0, 60.0),
    };
    let pool = ThreadPool::new(3);
    check(45, 30, &gen, |p| {
        let mut rng = Rng::new(113);
        let mut c = seed_centroids(p, &mut rng);
        let mut old = vec![0f32; p.k * p.n];
        let mut st_s = LloydState::new(p.m);
        let mut st_p = LloydState::new(p.m);
        let mut cnt_s = Counters::new();
        let mut cnt_p = Counters::new();
        let engine = ElkanEngine::default();
        for _ in 0..4 {
            let a = engine.assign_step(&p.points, &c, p.m, p.n, p.k, &mut st_s, &mut cnt_s);
            let b = engine.assign_step_parallel(
                &pool, &p.points, &c, p.m, p.n, p.k, &mut st_p, &mut cnt_p,
            );
            if a.labels != b.labels
                || a.mins != b.mins
                || a.counts != b.counts
                || (a.objective - b.objective).abs() > 1e-6 * a.objective.abs() + 1e-9
            {
                return false;
            }
            old.copy_from_slice(&c);
            kernels::update_centroids(&a.sums, &a.counts, &mut c, p.k, p.n);
            st_s.apply_update(&old, &c, p.k, p.n);
            st_p.apply_update(&old, &c, p.k, p.n);
        }
        cnt_s.distance_evals == cnt_p.distance_evals && cnt_s.pruned_evals == cnt_p.pruned_evals
    });
}

#[test]
fn prop_quantised_elkan_exact_labels_under_coarse_quanta() {
    // Wide coordinate ranges force coarse u16 quanta for the Elkan
    // lower-bound matrix. The rounding contract (floor on store, ceil on
    // drift relaxation) may only ever weaken a bound, so labels, mins,
    // and the centroid trajectory must still match the exact panel
    // engine at every step — only the pruning rate is allowed to suffer.
    let gen = ClusterProblemGen {
        m_range: (20, 1500),
        n_range: (1, 12),
        k_max: 8,
        coord_range: (-5000.0, 5000.0),
    };
    let panel = PanelEngine;
    let elkan = ElkanEngine::default();
    check(47, 40, &gen, |p| {
        let mut rng = Rng::new(131);
        let c0 = seed_centroids(p, &mut rng);
        let mut c_a = c0.clone();
        let mut c_b = c0;
        let mut st_a = LloydState::new(p.m);
        let mut st_b = LloydState::new(p.m);
        let mut cnt_a = Counters::new();
        let mut cnt_b = Counters::new();
        let mut old = vec![0f32; p.k * p.n];
        for _ in 0..5 {
            let a = panel.assign_step(&p.points, &c_a, p.m, p.n, p.k, &mut st_a, &mut cnt_a);
            let b = elkan.assign_step(&p.points, &c_b, p.m, p.n, p.k, &mut st_b, &mut cnt_b);
            if a.labels != b.labels || a.mins != b.mins || a.counts != b.counts {
                return false;
            }
            old.copy_from_slice(&c_a);
            kernels::update_centroids(&a.sums, &a.counts, &mut c_a, p.k, p.n);
            st_a.apply_update(&old, &c_a, p.k, p.n);
            old.copy_from_slice(&c_b);
            kernels::update_centroids(&b.sums, &b.counts, &mut c_b, p.k, p.n);
            st_b.apply_update(&old, &c_b, p.k, p.n);
            if c_a != c_b {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_hybrid_parallel_step_identical_to_serial() {
    // Pool-parallel hybrid assignment must match the serial hybrid path
    // point-for-point — including taking the Hamerly→Elkan switch on the
    // same step, because the decision reads per-step counters that are
    // summed across workers before the rescan rate is computed.
    let gen = ClusterProblemGen {
        m_range: (1, 3000), // crosses the 2·BLOCK_ROWS parallel threshold
        n_range: (1, 10),
        k_max: 6,
        coord_range: (-60.0, 60.0),
    };
    let pool = ThreadPool::new(3);
    check(48, 30, &gen, |p| {
        let mut rng = Rng::new(137);
        let mut c = seed_centroids(p, &mut rng);
        let mut old = vec![0f32; p.k * p.n];
        let mut st_s = LloydState::new(p.m);
        let mut st_p = LloydState::new(p.m);
        let mut cnt_s = Counters::new();
        let mut cnt_p = Counters::new();
        let engine = HybridEngine::default();
        for _ in 0..4 {
            let a = engine.assign_step(&p.points, &c, p.m, p.n, p.k, &mut st_s, &mut cnt_s);
            let b = engine.assign_step_parallel(
                &pool, &p.points, &c, p.m, p.n, p.k, &mut st_p, &mut cnt_p,
            );
            if a.labels != b.labels
                || a.mins != b.mins
                || a.counts != b.counts
                || (a.objective - b.objective).abs() > 1e-6 * a.objective.abs() + 1e-9
            {
                return false;
            }
            old.copy_from_slice(&c);
            kernels::update_centroids(&a.sums, &a.counts, &mut c, p.k, p.n);
            st_s.apply_update(&old, &c, p.k, p.n);
            st_p.apply_update(&old, &c, p.k, p.n);
        }
        cnt_s.distance_evals == cnt_p.distance_evals
            && cnt_s.pruned_evals == cnt_p.pruned_evals
            && cnt_s.hybrid_switches == cnt_p.hybrid_switches
    });
}

#[test]
fn prop_dispatched_simd_bit_identical_to_scalar() {
    // Gating roofline contract: the runtime-dispatched SIMD kernels must
    // reproduce the scalar lane-tiled reduction bit-for-bit — identical
    // labels, mins, sums, and objective bits — across random shapes, on
    // both the serial and the pooled panel paths. This is the only test
    // in this binary that toggles the process-wide ISA; every other test
    // is ISA-agnostic precisely because of this equivalence.
    let gen = ClusterProblemGen {
        m_range: (1, 3000),
        n_range: (1, 24),
        k_max: 8,
        coord_range: (-60.0, 60.0),
    };
    let pool = ThreadPool::new(3);
    let best = detect_isa();
    check(46, 30, &gen, |p| {
        let mut rng = Rng::new(127);
        let c = seed_centroids(p, &mut rng);
        let panel = PanelEngine;
        let run = |isa| {
            set_isa(isa).expect("selected isa must be available");
            let mut st_s = LloydState::new(p.m);
            let mut st_p = LloydState::new(p.m);
            let mut cnt = Counters::new();
            let a = panel.assign_step(&p.points, &c, p.m, p.n, p.k, &mut st_s, &mut cnt);
            let b = panel.assign_step_parallel(
                &pool, &p.points, &c, p.m, p.n, p.k, &mut st_p, &mut cnt,
            );
            (a, b)
        };
        let (s_ser, s_par) = run(DistanceIsa::Scalar);
        let (v_ser, v_par) = run(best);
        s_ser.labels == v_ser.labels
            && s_ser.mins == v_ser.mins
            && s_ser.sums == v_ser.sums
            && s_ser.objective.to_bits() == v_ser.objective.to_bits()
            && s_par.labels == v_par.labels
            && s_par.mins == v_par.mins
            && s_par.sums == v_par.sums
            && s_par.objective.to_bits() == v_par.objective.to_bits()
    });
}

#[test]
fn prop_fused_f16_reads_bit_identical_to_decode_path() {
    // Decode-free f16 contract, fuzzed over shapes: for dtype = f16 ×
    // codec = none the fused reader slices raw halfwords off the mapping
    // and widens per element — it must hand back exactly the same f32
    // bytes as the decode-to-slab path AND the reference per-element
    // quantisation, across random (m, n) including masked SIMD tails
    // (n % 32 != 0) and block geometries down to single-row blocks. Byte
    // equality here makes every engine × ISA combination downstream
    // bit-identical for free (the engines only ever see these buffers);
    // CI re-runs this binary under BIGMEANS_ISA=scalar and =auto on top.
    use bigmeans::store::{copy_to_store, BlockStore, Codec, Dtype, StoreOptions};
    use bigmeans::util::half::{f16_from_f32, f32_from_f16};
    use std::sync::atomic::{AtomicUsize, Ordering};

    static TRIAL: AtomicUsize = AtomicUsize::new(0);
    let dir = std::env::temp_dir().join("bigmeans_engine_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let gen = ClusterProblemGen {
        m_range: (1, 2000),
        n_range: (1, 40), // crosses the 32-lane tile boundary
        k_max: 6,
        coord_range: (-60.0, 60.0),
    };
    check(49, 25, &gen, |p| {
        let trial = TRIAL.fetch_add(1, Ordering::Relaxed);
        let path = dir.join(format!("{}_fused_{trial}.bmx", std::process::id()));
        let block_rows = 1 + p.m % 117; // includes single-row blocks (m % 117 == 0)
        let opts = StoreOptions {
            block_rows,
            dtype: Dtype::F16,
            codec: Codec::None,
            ..StoreOptions::default()
        };
        let d = Dataset::from_vec("fused_prop", p.points.clone(), p.m, p.n);
        copy_to_store(&d, &path, opts).unwrap();
        let fused = BlockStore::open(&path).unwrap();
        if !fused.is_mmap() {
            let _ = std::fs::remove_file(&path);
            return true; // the fused path needs mmap backing on this target
        }
        let decoded = BlockStore::open(&path).unwrap();
        decoded.set_fused_f16(false);
        let reference: Vec<f32> =
            p.points.iter().map(|&v| f32_from_f16(f16_from_f32(v))).collect();
        let mut a = vec![0f32; p.m * p.n];
        let mut b = vec![0f32; p.m * p.n];
        fused.read_rows(0, &mut a);
        decoded.read_rows(0, &mut b);
        let mut ok = fused.fused_f16_active() && a == b && a == reference;
        // Scattered gather, reverse order so consecutive draws hop blocks.
        let idx: Vec<usize> = (0..p.m).rev().step_by(2).collect();
        let mut ga = vec![0f32; idx.len() * p.n];
        let mut gb = vec![0f32; idx.len() * p.n];
        fused.sample_rows(&idx, &mut ga);
        decoded.sample_rows(&idx, &mut gb);
        ok = ok && ga == gb;
        for (slot, &i) in idx.iter().enumerate() {
            ok = ok && ga[slot * p.n..(slot + 1) * p.n] == reference[i * p.n..(i + 1) * p.n];
        }
        let _ = std::fs::remove_file(&path);
        if !ok {
            eprintln!(
                "fused f16 diverged on m={} n={} block_rows={block_rows}",
                p.m, p.n
            );
        }
        ok
    });
}

fn blobs(m: usize, n: usize, k_true: usize, seed: u64) -> Dataset {
    Synth::GaussianMixture {
        m,
        n,
        k_true,
        spread: 0.3,
        box_half_width: 20.0,
    }
    .generate("engines", seed)
}

#[test]
fn pruning_pipelines_match_panel_and_prune_on_blobs() {
    // Whole-pipeline equivalence: sequential Big-means runs with the
    // bounded and Elkan kernels reproduce the panel run's numbers while
    // reporting a real pruning saving on separated blobs.
    let data = blobs(6_000, 4, 4, 11);
    let cfg = |kernel| {
        BigMeansConfig::new(4, 1024)
            .with_stop(StopCondition::MaxChunks(15))
            .with_parallel(ParallelMode::Sequential)
            .with_kernel(kernel)
            .with_seed(5)
    };
    let panel = BigMeans::new(cfg(KernelEngineKind::Panel)).run(&data).unwrap();
    assert_eq!(panel.counters.pruned_evals, 0, "panel must never prune");
    for kind in [KernelEngineKind::Bounded, KernelEngineKind::Elkan, KernelEngineKind::Hybrid] {
        let pruned = BigMeans::new(cfg(kind)).run(&data).unwrap();
        assert!(
            (panel.objective - pruned.objective).abs() <= 1e-6 * panel.objective.abs(),
            "{kind:?}: objectives diverged: {} vs {}",
            panel.objective,
            pruned.objective
        );
        assert_eq!(panel.assignment, pruned.assignment, "{kind:?}");
        assert_eq!(panel.counters.chunks, pruned.counters.chunks, "{kind:?}");
        assert!(pruned.counters.pruned_evals > 0, "{kind:?}: no pruning on blobs");
        assert!(
            pruned.counters.distance_evals < panel.counters.distance_evals,
            "{kind:?} ({}) did not save over panel ({})",
            pruned.counters.distance_evals,
            panel.counters.distance_evals
        );
    }
}

#[test]
fn bounded_engine_bit_identical_across_backends() {
    // The out-of-core determinism contract holds under the bounded engine
    // too: mem, mmap, and buffered runs are bit-for-bit identical.
    let data = blobs(12_000, 5, 4, 12);
    let dir = std::env::temp_dir().join("bigmeans_engine_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{}_bounded.bmx", std::process::id()));
    save_bmx(&data, &path).unwrap();
    let mapped = BmxSource::open(&path).unwrap();
    let buffered = BmxSource::open_buffered(&path).unwrap();

    let run = |src: &dyn bigmeans::DataSource| {
        BigMeans::new(
            BigMeansConfig::new(4, 1024)
                .with_stop(StopCondition::MaxChunks(12))
                .with_parallel(ParallelMode::Sequential)
                .with_kernel(KernelEngineKind::Bounded)
                .with_seed(21),
        )
        .run(src)
        .unwrap()
    };
    let mem = run(&data);
    let via_mmap = run(&mapped);
    let via_pread = run(&buffered);
    assert!(mem.counters.pruned_evals > 0);
    for (label, other) in [("mmap", &via_mmap), ("buffered", &via_pread)] {
        assert_eq!(mem.objective.to_bits(), other.objective.to_bits(), "{label}");
        assert_eq!(mem.centroids, other.centroids, "{label}");
        assert_eq!(mem.assignment, other.assignment, "{label}");
        assert_eq!(mem.counters, other.counters, "{label}");
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn bounded_chunk_parallel_single_worker_reproducible() {
    // The ticketed chunk-parallel pipeline stays deterministic at one
    // worker with the bounded engine.
    let data = blobs(5_000, 4, 3, 13);
    let mk = || {
        let mut cfg = BigMeansConfig::new(3, 512)
            .with_stop(StopCondition::MaxChunks(8))
            .with_parallel(ParallelMode::ChunkParallel)
            .with_kernel(KernelEngineKind::Bounded)
            .with_seed(9);
        cfg.threads = 1;
        cfg
    };
    let a = BigMeans::new(mk()).run(&data).unwrap();
    let b = BigMeans::new(mk()).run(&data).unwrap();
    assert_eq!(a.centroids, b.centroids);
    assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    assert_eq!(a.counters, b.counters);
    assert!(a.counters.pruned_evals > 0);
}

#[test]
fn bounded_streaming_and_vns_run_clean() {
    // The remaining pipelines accept the bounded kernel and produce
    // finite, sane results (full equivalence is covered above; here we
    // exercise the wiring).
    use bigmeans::coordinator::stream::{produce_from_source, ChunkQueue, StreamingBigMeans};
    use bigmeans::coordinator::vns::{run_vns, VnsConfig};
    use std::sync::Arc;

    let data = blobs(4_000, 3, 3, 14);
    let base = BigMeansConfig::new(3, 512)
        .with_stop(StopCondition::MaxChunks(10))
        .with_parallel(ParallelMode::Sequential)
        .with_kernel(KernelEngineKind::Bounded)
        .with_seed(17);

    let vns = run_vns(&VnsConfig::new(base.clone()), &data).unwrap();
    assert!(vns.inner.objective.is_finite());
    assert!(vns.inner.counters.pruned_evals > 0);

    let engine = StreamingBigMeans::new(base, 3);
    let queue = ChunkQueue::new(4);
    let producer = {
        let q = Arc::clone(&queue);
        let src = blobs(4_000, 3, 3, 14);
        std::thread::spawn(move || {
            produce_from_source(&src, &q, 512);
            q.close();
        })
    };
    let r = engine.run(&queue);
    producer.join().unwrap();
    assert!(r.best_chunk_objective.is_finite());
    assert!(r.chunks_processed > 0);
    assert!(r.counters.pruned_evals > 0);
}
