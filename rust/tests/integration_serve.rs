//! Integration: serve mode end to end. A live daemon on loopback answers
//! concurrent batched assign/score queries while the model registry is
//! hot-swapped underneath it — every response must be bit-identical to
//! the offline `assign_only` pass *for the generation that answered it*,
//! no request may be dropped, and the stats document must account for
//! every swap. This is the serving contract: a label handed out over the
//! wire never disagrees with what a batch job would have computed.

use std::sync::Arc;
use std::time::Duration;

use bigmeans::kernels::assign_only;
use bigmeans::metrics::Counters;
use bigmeans::serve::{spawn_watcher, Client, ModelArtifact, ModelRegistry, ServeOptions, Server};
use bigmeans::util::json::Json;
use bigmeans::util::rng::Rng;

fn centroids(rng: &mut Rng, k: usize, n: usize) -> Vec<f32> {
    (0..k * n).map(|_| rng.f32() * 20.0 - 10.0).collect()
}

#[test]
fn daemon_serves_bit_identical_labels_across_hot_swaps_without_drops() {
    let (k, n) = (9, 5);
    let mut rng = Rng::new(0xD05E);
    let generations: Vec<Vec<f32>> = (0..3).map(|_| centroids(&mut rng, k, n)).collect();
    let batch_rows = 257; // odd on purpose: exercises ragged row carving
    let points: Vec<f32> =
        (0..batch_rows * n).map(|_| rng.f32() * 20.0 - 10.0).collect();
    // Offline truth per generation, from the exact kernel the daemon shards.
    let truth: Vec<(Vec<u32>, Vec<f32>)> = generations
        .iter()
        .map(|c| {
            let mut counters = Counters::new();
            assign_only(&points, c, batch_rows, n, k, &mut counters)
        })
        .collect();

    let boot =
        ModelArtifact::new(k, n, 1, 0.0, Json::Null, generations[0].clone()).unwrap();
    let registry = ModelRegistry::new(boot);
    let server = Server::bind(
        "127.0.0.1:0",
        Arc::clone(&registry),
        ServeOptions { threads: 3, max_batch_rows: 1 << 16 },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let runner = std::thread::spawn(move || server.run().unwrap());

    let workers = 4usize;
    let per_worker = 30usize;
    let answered: Vec<u64> = std::thread::scope(|scope| {
        // Publisher: two hot-swaps land while the query threads are live.
        let publisher = {
            let registry = Arc::clone(&registry);
            let generations = &generations;
            scope.spawn(move || {
                for (i, c) in generations.iter().enumerate().skip(1) {
                    std::thread::sleep(Duration::from_millis(40));
                    let artifact =
                        ModelArtifact::new(k, n, (i + 1) as u64, 0.0, Json::Null, c.clone())
                            .unwrap();
                    registry.publish(artifact);
                }
            })
        };
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let addr = addr.clone();
                let points = &points;
                let truth = &truth;
                scope.spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    let mut answered = 0u64;
                    for i in 0..per_worker {
                        if (w + i) % 2 == 0 {
                            let (generation, labels) =
                                client.assign(points, batch_rows, n).unwrap();
                            let (want_labels, _) = &truth[generation as usize - 1];
                            assert_eq!(
                                &labels, want_labels,
                                "assign labels must match offline generation {generation}"
                            );
                        } else {
                            let (generation, labels, dists, objective) =
                                client.score(points, batch_rows, n).unwrap();
                            let (want_labels, want_mins) =
                                &truth[generation as usize - 1];
                            assert_eq!(&labels, want_labels);
                            let same = dists
                                .iter()
                                .zip(want_mins)
                                .all(|(a, b)| a.to_bits() == b.to_bits());
                            assert!(
                                same,
                                "score dists must be bit-identical for generation \
                                 {generation}"
                            );
                            let want_obj: f64 =
                                want_mins.iter().map(|&d| f64::from(d)).sum();
                            assert_eq!(objective.to_bits(), want_obj.to_bits());
                        }
                        answered += 1;
                        // Pacing so the publishes land mid-stream.
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    answered
                })
            })
            .collect();
        publisher.join().unwrap();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // Zero dropped requests: every query got exactly one answer.
    assert_eq!(answered.iter().sum::<u64>(), (workers * per_worker) as u64);
    assert_eq!(registry.generation(), 3);

    let mut client = Client::connect(&addr).unwrap();
    let (generation, json) = client.stats().unwrap();
    assert_eq!(generation, 3, "stats must report the post-swap generation");
    let doc = Json::parse(&json).unwrap();
    let get = |key: &str| doc.get(key).and_then(|v| v.as_f64()).unwrap();
    assert!(get("requests") >= (workers * per_worker) as f64);
    assert_eq!(get("swaps"), 2.0);
    assert_eq!(get("errors"), 0.0);
    assert!(get("rows") >= (workers * per_worker * batch_rows) as f64);
    assert!(get("p99_ms") >= get("p50_ms"));
    assert!(get("qps") > 0.0);
    assert_eq!(client.ping().unwrap(), 3);
    client.shutdown().unwrap();
    runner.join().unwrap();
}

#[test]
fn malformed_batches_get_error_responses_on_a_live_connection() {
    let (k, n) = (3, 4);
    let mut rng = Rng::new(0xE44);
    let boot =
        ModelArtifact::new(k, n, 1, 0.0, Json::Null, centroids(&mut rng, k, n)).unwrap();
    let registry = ModelRegistry::new(boot);
    let server = Server::bind(
        "127.0.0.1:0",
        Arc::clone(&registry),
        ServeOptions { threads: 1, max_batch_rows: 8 },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let runner = std::thread::spawn(move || server.run().unwrap());

    let mut client = Client::connect(&addr).unwrap();
    // Wrong dimensionality: named error, connection stays up.
    let bad_dims = vec![0.0f32; 6 * (n + 1)];
    let err = client.assign(&bad_dims, 6, n + 1).unwrap_err();
    assert!(format!("{err}").contains("dims mismatch"), "got: {err}");
    // Over the batch cap: named error, connection stays up.
    let too_big = vec![0.0f32; 9 * n];
    let err = client.assign(&too_big, 9, n).unwrap_err();
    assert!(format!("{err}").contains("exceeds cap"), "got: {err}");
    // The same connection still answers a well-formed batch.
    let fine = vec![0.5f32; 2 * n];
    let (generation, labels) = client.assign(&fine, 2, n).unwrap();
    assert_eq!(generation, 1);
    assert_eq!(labels.len(), 2);
    let (_, json) = client.stats().unwrap();
    let doc = Json::parse(&json).unwrap();
    assert_eq!(doc.get("errors").and_then(|v| v.as_f64()).unwrap(), 2.0);
    client.shutdown().unwrap();
    runner.join().unwrap();
}

#[test]
fn file_watcher_feeds_the_daemon_published_artifacts() {
    // The stream→registry publish contract end to end through the file
    // system: save artifact → serve with a watcher → rewrite the artifact
    // (as `--publish` does on an improvement) → the daemon answers from
    // the refreshed model with no restart.
    let (k, n) = (4, 3);
    let mut rng = Rng::new(0xFEED);
    let dir = std::env::temp_dir().join("bigmeans_serve_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{}_model.bmm", std::process::id()));
    let c1 = centroids(&mut rng, k, n);
    ModelArtifact::new(k, n, 1, 0.0, Json::Null, c1).unwrap().save(&path).unwrap();

    let boot = ModelArtifact::load(&path).unwrap();
    let identity = (boot.generation, boot.payload_crc());
    let registry = ModelRegistry::new(boot);
    let server =
        Server::bind("127.0.0.1:0", Arc::clone(&registry), ServeOptions::default()).unwrap();
    let addr = server.local_addr().to_string();
    let stop = server.shutdown_handle();
    let watcher = spawn_watcher(
        Arc::clone(&registry),
        path.clone(),
        Duration::from_millis(30),
        Arc::clone(&stop),
        identity,
    );
    let runner = std::thread::spawn(move || server.run().unwrap());

    // A concurrent trainer improves the model: a bigger payload guarantees
    // the watcher's (len, mtime) stat check fires even on coarse-mtime
    // filesystems. Same n — the daemon's schema never changes.
    std::thread::sleep(Duration::from_millis(80));
    let c2 = centroids(&mut rng, k + 2, n);
    ModelArtifact::new(k + 2, n, 2, 0.0, Json::Null, c2.clone())
        .unwrap()
        .save(&path)
        .unwrap();

    let mut client = Client::connect(&addr).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while client.ping().unwrap() < 2 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(client.ping().unwrap(), 2, "watcher must hot-swap the rewrite");

    let batch = 33usize;
    let points: Vec<f32> = (0..batch * n).map(|_| rng.f32() * 20.0 - 10.0).collect();
    let (generation, labels) = client.assign(&points, batch, n).unwrap();
    assert_eq!(generation, 2);
    let mut counters = Counters::new();
    let (want, _) = assign_only(&points, &c2, batch, n, k + 2, &mut counters);
    assert_eq!(labels, want, "answers must come from the refreshed centroids");

    client.shutdown().unwrap();
    runner.join().unwrap();
    watcher.join().unwrap();
    let _ = std::fs::remove_file(&path);
}
