//! Integration: `.bmx` v2 error paths.
//!
//! The format's safety story has three legs, each exercised here end to
//! end through both open paths (mmap and buffered pread):
//!
//! 1. a corrupted payload is rejected at open with the documented
//!    checksum diagnostic — clustering garbage floats is never an option;
//! 2. legacy v1 files (16-byte header, no checksum) still load — with a
//!    stderr warning — and serve identical values;
//! 3. payloads beyond [`BMX_VERIFY_EAGER_LIMIT`] skip the eager CRC scan
//!    (an O(file) scan would defeat the out-of-core design), exercised via
//!    a header-forged sparse file so the test costs kilobytes of disk, not
//!    4 GiB.

use std::fs::File;
use std::io::Write;
use std::path::PathBuf;

use bigmeans::data::bmx::{
    save_bmx, BmxSource, BMX_HEADER_LEN_V2, BMX_MAGIC, BMX_MAGIC_V2, BMX_VERIFY_EAGER_LIMIT,
};
use bigmeans::data::Dataset;
use bigmeans::DataSource;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("bigmeans_bmx_v2_errors");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{name}", std::process::id()))
}

fn toy() -> Dataset {
    Dataset::from_vec("toy", (0..60).map(|x| x as f32 * 0.25 - 3.0).collect(), 15, 4)
}

#[test]
fn corrupted_crc_rejected_with_documented_error() {
    let p = tmp("corrupt.bmx");
    save_bmx(&toy(), &p).unwrap();
    let mut bytes = std::fs::read(&p).unwrap();
    // Flip a payload bit well past the header.
    bytes[BMX_HEADER_LEN_V2 + 23] ^= 0x10;
    std::fs::write(&p, &bytes).unwrap();
    let errors = [
        BmxSource::open(&p).unwrap_err().to_string(),
        BmxSource::open_buffered(&p).unwrap_err().to_string(),
    ];
    for err in errors {
        assert!(
            err.contains("checksum mismatch") && err.contains("corrupt"),
            "documented diagnostic expected, got: {err}"
        );
    }
    let _ = std::fs::remove_file(&p);
}

#[test]
fn header_crc_field_corruption_also_rejected() {
    // Corruption in the *stored* checksum (not the payload) must be caught
    // by the same comparison.
    let p = tmp("hdrfield.bmx");
    save_bmx(&toy(), &p).unwrap();
    let mut bytes = std::fs::read(&p).unwrap();
    bytes[16] ^= 0xFF; // first byte of the stored CRC-32
    std::fs::write(&p, &bytes).unwrap();
    assert!(BmxSource::open(&p).unwrap_err().to_string().contains("checksum"));
    let _ = std::fs::remove_file(&p);
}

#[test]
fn legacy_v1_accepted_and_value_identical() {
    // Hand-build a v1 file: 16-byte header, no checksum. It must load
    // through both paths (with a stderr warning) and serve the exact
    // payload bytes.
    let p = tmp("legacy.bmx");
    let d = toy();
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&BMX_MAGIC);
    bytes.extend_from_slice(&(d.m() as u64).to_le_bytes());
    bytes.extend_from_slice(&(d.n() as u32).to_le_bytes());
    for &v in d.points() {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(&p, &bytes).unwrap();
    for src in [BmxSource::open(&p).unwrap(), BmxSource::open_buffered(&p).unwrap()] {
        assert_eq!((src.m(), src.n()), (d.m(), d.n()));
        let mut all = vec![0f32; d.m() * d.n()];
        src.read_rows(0, &mut all);
        assert_eq!(all, d.points());
        // Even a corrupted v1 payload loads: there is no checksum to
        // catch it — which is exactly why v1 warns.
    }
    let _ = std::fs::remove_file(&p);
}

#[test]
fn oversized_payload_skips_eager_crc_validation() {
    // Forge a v2 header promising a payload just past the eager-verify
    // limit, with a garbage checksum, backed by a sparse file (set_len
    // allocates holes, not blocks). If the skip path were broken in either
    // direction this test catches it:
    //  * scan attempted → the garbage checksum would fail the open;
    //  * size accounting off → the truncation check would fail the open.
    let n: u32 = 2;
    let m: u64 = BMX_VERIFY_EAGER_LIMIT / (4 * n as u64) + 16;
    let payload = m * n as u64 * 4;
    assert!(payload > BMX_VERIFY_EAGER_LIMIT);
    let p = tmp("huge.bmx");
    {
        let mut f = File::create(&p).unwrap();
        f.write_all(&BMX_MAGIC_V2).unwrap();
        f.write_all(&m.to_le_bytes()).unwrap();
        f.write_all(&n.to_le_bytes()).unwrap();
        f.write_all(&0xDEAD_BEEFu32.to_le_bytes()).unwrap(); // garbage CRC
        f.write_all(&[0u8; 12]).unwrap(); // reserved
        f.set_len(BMX_HEADER_LEN_V2 as u64 + payload).unwrap();
    }
    for src in [BmxSource::open(&p).unwrap(), BmxSource::open_buffered(&p).unwrap()] {
        assert_eq!(src.m() as u64, m);
        assert_eq!(src.n() as u32, n);
        // Rows in file holes read as zeros — including the very last row.
        let mut row = vec![1.0f32; n as usize];
        src.read_rows((m - 1) as usize, &mut row);
        assert_eq!(row, vec![0.0; n as usize]);
        let mut gather = vec![1.0f32; 2 * n as usize];
        src.sample_rows(&[0, (m / 2) as usize], &mut gather);
        assert!(gather.iter().all(|&v| v == 0.0));
    }
    let _ = std::fs::remove_file(&p);
}

#[test]
fn truncated_v2_payload_rejected() {
    // A v2 header promising more rows than the file holds must fail the
    // size check up front (not at first read).
    let p = tmp("trunc.bmx");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&BMX_MAGIC_V2);
    bytes.extend_from_slice(&100u64.to_le_bytes());
    bytes.extend_from_slice(&4u32.to_le_bytes());
    bytes.extend_from_slice(&0u32.to_le_bytes());
    bytes.extend_from_slice(&[0u8; 12]);
    bytes.extend_from_slice(&[0u8; 64]); // far short of 100×4×4 bytes
    std::fs::write(&p, &bytes).unwrap();
    let err = BmxSource::open(&p).unwrap_err().to_string();
    assert!(err.contains("truncated"), "got: {err}");
    let _ = std::fs::remove_file(&p);
}
