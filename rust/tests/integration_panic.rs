//! End-to-end crash-path and report-pipeline tests driving the real
//! `bigmeans` binary as a subprocess.
//!
//! The crash test sets `BIGMEANS_PANIC_IN_SHOT` so the first shot panics
//! inside its `shot.lloyd` span, then asserts the two guarantees the
//! flight recorder makes about a dying run:
//!
//! * the `--trace` file is still valid JSON (the panic hook flushes the
//!   tracer and closes the document before the process unwinds), and
//! * the `--diag` dump exists, parses, and names the panicking span.
//!
//! The report test exercises the happy path of the same plumbing:
//! `cluster --report` → `report` (HTML render) → `metrics-lint`.

use std::path::{Path, PathBuf};
use std::process::Command;

use bigmeans::util::json::Json;

/// A unique scratch directory under the target tmpdir.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("bigmeans_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a small headerless CSV: `m` rows in 4 dims, three well-separated
/// blobs laid out deterministically (no RNG needed — the subprocess only
/// has to iterate, not find good clusters).
fn write_csv(path: &Path, m: usize) {
    let mut text = String::with_capacity(m * 32);
    for i in 0..m {
        let center = (i % 3) as f64 * 10.0;
        let jitter = ((i * 7919) % 100) as f64 / 200.0; // 0.0 .. 0.5
        for d in 0..4 {
            if d > 0 {
                text.push(',');
            }
            text.push_str(&format!("{:.4}", center + jitter + d as f64 * 0.01));
        }
        text.push('\n');
    }
    std::fs::write(path, text).unwrap();
}

fn bigmeans_cmd(dir: &Path) -> Command {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_bigmeans"));
    cmd.current_dir(dir);
    cmd
}

fn parse_file(path: &Path) -> Json {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    Json::parse(&text).unwrap_or_else(|e| panic!("parse {}: {e}", path.display()))
}

#[test]
fn panic_mid_run_leaves_valid_trace_and_diagnostics() {
    let dir = scratch("panic");
    let csv = dir.join("data.csv");
    write_csv(&csv, 600);
    let trace = dir.join("trace.json");
    let diag = dir.join("diag.json");

    // --mode chunks routes through ShotExecutor::run_shot, where the
    // injection hook lives; the worker panics inside `shot.lloyd`. The
    // 1s time budget bounds the coordinator's condvar wait: panicked
    // workers never report progress, so the deadline is what wakes it.
    let out = bigmeans_cmd(&dir)
        .args(["cluster", "data.csv", "--k", "3", "--s", "128", "--time", "1"])
        .args(["--chunks", "12", "--mode", "chunks", "--threads", "2"])
        .args(["--skip-final", "--trace", "trace.json", "--diag", "diag.json"])
        .env("BIGMEANS_PANIC_IN_SHOT", "1")
        .output()
        .expect("spawn bigmeans");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(!out.status.success(), "injected panic must fail the run\n{stderr}");
    assert!(
        stderr.contains("flight recorder: diagnostics dumped"),
        "crash handler should announce the dump\n{stderr}"
    );

    // The trace survived the panic as a parseable document: the hook
    // flushed the buffered spans and closed the JSON before unwinding.
    let trace_doc = parse_file(&trace);
    let events = trace_doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("trace document has a traceEvents array")
        .to_vec();
    assert!(
        !events.is_empty(),
        "spans completed before the panic (sample/reseed) must be present"
    );

    // The diagnostics dump names the panic and the span it died inside.
    let diag_doc = parse_file(&diag);
    assert_eq!(
        diag_doc.get("schema").and_then(|v| v.as_str()),
        Some("bigmeans.diagnostics.v1")
    );
    assert_eq!(diag_doc.get("trigger").and_then(|v| v.as_str()), Some("panic"));
    let crash = diag_doc.get("crash").expect("crash context present");
    assert_eq!(crash.get("kind").and_then(|v| v.as_str()), Some("panic"));
    let message = crash.get("message").and_then(|v| v.as_str()).unwrap_or("");
    assert!(message.contains("injected shot panic"), "crash message: {message}");
    let panicking =
        crash.get("panicking_span").and_then(|v| v.as_str()).unwrap_or("");
    assert!(
        panicking.contains("shot.lloyd"),
        "panicking span should be shot.lloyd, got '{panicking}'"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

/// SIGTERM mid-run must terminate the process (with SIGTERM exit status,
/// not a hang) after the watcher thread writes the diagnostics dump. This
/// is the regression test for the old async-signal-handler design, which
/// took mutexes and allocated inside the handler and could deadlock.
#[cfg(unix)]
#[test]
fn sigterm_mid_run_dumps_diagnostics_and_dies() {
    use std::os::unix::process::ExitStatusExt;

    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    const SIGTERM: i32 = 15;

    let dir = scratch("sigterm");
    let csv = dir.join("data.csv");
    write_csv(&csv, 600);
    let diag = dir.join("diag.json");

    // A generous time budget keeps the run alive until the signal lands;
    // if the kill were ever lost the run still exits on its own.
    let mut child = bigmeans_cmd(&dir)
        .args(["cluster", "data.csv", "--k", "3", "--s", "128", "--time", "30"])
        .args(["--mode", "chunks", "--threads", "2"])
        .args(["--skip-final", "--diag", "diag.json"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn bigmeans");
    // Let it install the handlers and get a few shots in.
    std::thread::sleep(std::time::Duration::from_millis(1500));
    assert_eq!(unsafe { kill(child.id() as i32, SIGTERM) }, 0, "kill(SIGTERM) failed");
    let out = child.wait_with_output().expect("wait for bigmeans");
    let stderr = String::from_utf8_lossy(&out.stderr);

    assert_eq!(
        out.status.signal(),
        Some(SIGTERM),
        "process must die by SIGTERM after the dump, not hang or exit clean\n{stderr}"
    );
    assert!(
        stderr.contains("flight recorder: diagnostics dumped"),
        "watcher should announce the dump\n{stderr}"
    );
    let diag_doc = parse_file(&diag);
    assert_eq!(diag_doc.get("trigger").and_then(|v| v.as_str()), Some("sigterm"));
    let crash = diag_doc.get("crash").expect("crash context present");
    assert_eq!(crash.get("kind").and_then(|v| v.as_str()), Some("signal"));
    assert_eq!(crash.get("signal").and_then(|v| v.as_str()), Some("SIGTERM"));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn report_pipeline_renders_and_lints_end_to_end() {
    let dir = scratch("report");
    let csv = dir.join("data.csv");
    write_csv(&csv, 600);

    let out = bigmeans_cmd(&dir)
        .args(["cluster", "data.csv", "--k", "3", "--s", "128"])
        .args(["--chunks", "10", "--mode", "chunks", "--threads", "2"])
        .args(["--skip-final", "--report", "report.json"])
        .output()
        .expect("spawn bigmeans");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "cluster --report failed\n{stderr}");

    // The report parses, carries the versioned schema, and has shots.
    let doc = parse_file(&dir.join("report.json"));
    assert_eq!(
        doc.get("schema").and_then(|v| v.as_str()),
        Some("bigmeans.run_report.v1")
    );
    let shots = doc.get("shots").and_then(|v| v.as_arr()).unwrap().to_vec();
    assert!(!shots.is_empty(), "chunk shots must be recorded");

    // The same document passes the CI lint gate...
    let lint = bigmeans_cmd(&dir)
        .args(["metrics-lint", "report.json"])
        .output()
        .expect("spawn bigmeans");
    assert!(
        lint.status.success(),
        "metrics-lint rejected the report\n{}",
        String::from_utf8_lossy(&lint.stderr)
    );

    // ...and renders to a self-contained HTML document with SVG charts.
    let render = bigmeans_cmd(&dir)
        .args(["report", "report.json", "report.html"])
        .output()
        .expect("spawn bigmeans");
    assert!(
        render.status.success(),
        "report render failed\n{}",
        String::from_utf8_lossy(&render.stderr)
    );
    let html = std::fs::read_to_string(dir.join("report.html")).unwrap();
    assert!(html.contains("<svg"), "charts must be inline SVG");
    assert!(html.ends_with("</body></html>\n"));
    assert!(!html.contains("http://") && !html.contains("https://"), "self-contained");

    let _ = std::fs::remove_dir_all(&dir);
}
