//! Integration: the `.bmx` v3 block-store storage engine.
//!
//! Contracts pinned down here:
//!
//! 1. **Value transparency** — for f32 payloads every codec is lossless,
//!    so a seeded Big-means run (sequential, chunk-parallel, tuned) over a
//!    block store reproduces the in-memory run bit-for-bit.
//! 2. **Per-block integrity** — flipping one byte in block *i* leaves the
//!    file openable (open is O(index)), `verify_all` names block *i*, a
//!    read touching block *i* panics naming block *i*, and reads that
//!    avoid it stay clean.
//! 3. **Round trips** — every dtype × codec × backing combination decodes
//!    back to the expected values (exact for f32/f64, quantised for f16).
//! 4. **Legacy regression** — v1/v2 files keep loading through the
//!    version-sniffing loader, and the block backend rejects them with a
//!    reconversion hint.

use std::path::PathBuf;

use bigmeans::coordinator::config::{
    BigMeansConfig, KernelEngineKind, ParallelMode, StopCondition,
};
use bigmeans::coordinator::{produce_from_source, ChunkQueue, StreamingBigMeans};
use bigmeans::data::bmx::save_bmx;
use bigmeans::data::synth::Synth;
use bigmeans::data::{bmx_version, loader, DataBackend};
use bigmeans::store::{copy_to_store, BlockStore, Codec, Dtype, StoreOptions};
use bigmeans::tuner::{run_race, ArmSpec, TunerConfig};
use bigmeans::util::half::{f16_from_f32, f32_from_f16};
use bigmeans::{BigMeans, BigMeansResult, DataSource, Dataset};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("bigmeans_store_v3_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{name}", std::process::id()))
}

fn blobs(m: usize, n: usize, k_true: usize, seed: u64) -> Dataset {
    Synth::GaussianMixture {
        m,
        n,
        k_true,
        spread: 0.3,
        box_half_width: 25.0,
    }
    .generate("store", seed)
}

fn sequential_cfg(k: usize, s: usize, chunks: u64) -> BigMeansConfig {
    BigMeansConfig::new(k, s)
        .with_stop(StopCondition::MaxChunks(chunks))
        .with_parallel(ParallelMode::Sequential)
        .with_seed(42)
}

fn assert_bit_identical(a: &BigMeansResult, b: &BigMeansResult, label: &str) {
    assert_eq!(
        a.objective.to_bits(),
        b.objective.to_bits(),
        "{label}: objectives differ: {} vs {}",
        a.objective,
        b.objective
    );
    assert_eq!(a.centroids, b.centroids, "{label}: centroids differ");
    assert_eq!(a.assignment, b.assignment, "{label}: assignments differ");
    assert_eq!(a.counters, b.counters, "{label}: counters differ");
}

#[test]
fn roundtrip_matrix_dtype_codec_backing() {
    let d = blobs(1_000, 5, 3, 1);
    let f16_expected: Vec<f32> = d
        .points()
        .iter()
        .map(|&v| f32_from_f16(f16_from_f32(v)))
        .collect();
    for dtype in [Dtype::F32, Dtype::F64, Dtype::F16] {
        for codec in [Codec::None, Codec::Shuffle, Codec::Lz] {
            let p = tmp(&format!("rt_{}_{}.bmx", dtype.name(), codec.name()));
            let opts = StoreOptions {
                block_rows: 128,
                dtype,
                codec,
                threads: 2,
                ..StoreOptions::default()
            };
            assert_eq!(copy_to_store(&d, &p, opts).unwrap(), (1_000, 5));
            assert_eq!(bmx_version(&p).unwrap(), 3);
            for (backing, store) in [
                ("mmap", BlockStore::open(&p).unwrap()),
                ("buffered", BlockStore::open_buffered(&p).unwrap()),
            ] {
                let label = format!("{}/{}/{backing}", dtype.name(), codec.name());
                assert_eq!((store.m(), store.n()), (1_000, 5), "{label}");
                assert_eq!(store.dtype(), dtype, "{label}");
                assert_eq!(store.codec(), codec, "{label}");
                let mut all = vec![0f32; 1_000 * 5];
                store.read_rows(0, &mut all);
                match dtype {
                    Dtype::F16 => assert_eq!(all, f16_expected, "{label}"),
                    _ => assert_eq!(all, d.points(), "{label}"),
                }
                // Scattered gather agrees with the block reads.
                let idx = [999usize, 0, 127, 128, 129, 500, 500];
                let mut got = vec![0f32; idx.len() * 5];
                store.sample_rows(&idx, &mut got);
                for (slot, &i) in idx.iter().enumerate() {
                    assert_eq!(
                        got[slot * 5..(slot + 1) * 5],
                        all[i * 5..(i + 1) * 5],
                        "{label}: row {i}"
                    );
                }
            }
            let _ = std::fs::remove_file(&p);
        }
    }
}

#[test]
fn sequential_pipeline_bit_identical_mem_vs_block_all_codecs() {
    let data = blobs(30_000, 6, 5, 2);
    let run = |src: &dyn DataSource| {
        BigMeans::new(sequential_cfg(5, 2048, 20)).run(src).unwrap()
    };
    let mem = run(&data);
    assert!(mem.objective.is_finite());
    for codec in [Codec::None, Codec::Shuffle, Codec::Lz] {
        let p = tmp(&format!("seq_{}.bmx", codec.name()));
        let opts = StoreOptions { block_rows: 4096, codec, ..StoreOptions::default() };
        copy_to_store(&data, &p, opts).unwrap();
        let mapped = BlockStore::open(&p).unwrap();
        let buffered = BlockStore::open_buffered(&p).unwrap();
        assert_bit_identical(&mem, &run(&mapped), &format!("mem vs block/{codec:?}/mmap"));
        assert_bit_identical(
            &mem,
            &run(&buffered),
            &format!("mem vs block/{codec:?}/buffered"),
        );
        let _ = std::fs::remove_file(&p);
    }
}

#[test]
fn chunk_parallel_and_tuner_run_unmodified_on_block_backend() {
    // One worker keeps both pipelines deterministic, so the block store
    // must reproduce the in-memory numbers bit-for-bit — the acceptance
    // gate for "all coordinators and --mode tune run on --backend block".
    let data = blobs(12_000, 4, 3, 3);
    let p = tmp("coord.bmx");
    copy_to_store(&data, &p, StoreOptions::default()).unwrap();
    let store = BlockStore::open(&p).unwrap();

    let par = |src: &dyn DataSource| {
        let mut cfg = BigMeansConfig::new(3, 1024)
            .with_stop(StopCondition::MaxChunks(12))
            .with_parallel(ParallelMode::ChunkParallel)
            .with_seed(7);
        cfg.threads = 1;
        BigMeans::new(cfg).run(src).unwrap()
    };
    assert_bit_identical(&par(&data), &par(&store), "chunk-parallel mem vs block");

    let race = |src: &dyn DataSource| {
        let mut cfg = BigMeansConfig::new(3, 512)
            .with_stop(StopCondition::MaxChunks(10))
            .with_parallel(ParallelMode::ChunkParallel)
            .with_seed(11);
        cfg.threads = 1;
        let tuner = TunerConfig::default()
            .with_arms(vec![ArmSpec::new(0.5), ArmSpec::new(1.0), ArmSpec::new(2.0)]);
        run_race(&cfg, &tuner, src).unwrap()
    };
    let mem_race = race(&data);
    let block_race = race(&store);
    assert_eq!(
        mem_race.result.objective.to_bits(),
        block_race.result.objective.to_bits(),
        "tuned objective must match across backends"
    );
    assert_eq!(
        mem_race.validation_objective.to_bits(),
        block_race.validation_objective.to_bits()
    );
    assert_eq!(mem_race.chosen_chunk_rows, block_race.chosen_chunk_rows);
    let _ = std::fs::remove_file(&p);
}

#[test]
fn streaming_consumes_a_block_store() {
    let data = blobs(6_000, 3, 3, 4);
    let p = tmp("stream.bmx");
    let opts = StoreOptions { block_rows: 512, codec: Codec::Lz, ..StoreOptions::default() };
    copy_to_store(&data, &p, opts).unwrap();
    let store = BlockStore::open(&p).unwrap();

    let run = |src: &dyn DataSource| {
        let cfg = BigMeansConfig::new(3, 500)
            .with_stop(StopCondition::MaxChunks(50))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(5);
        let engine = StreamingBigMeans::new(cfg, 3);
        let queue = ChunkQueue::new(4);
        std::thread::scope(|scope| {
            let q = std::sync::Arc::clone(&queue);
            scope.spawn(move || {
                produce_from_source(src, &q, 500);
                q.close();
            });
            engine.run(&queue)
        })
    };
    let mem = run(&data);
    let ooc = run(&store);
    assert_eq!(mem.chunks_processed, 12); // ceil(6000 / 500)
    assert_eq!(ooc.chunks_processed, 12);
    assert_eq!(
        mem.best_chunk_objective.to_bits(),
        ooc.best_chunk_objective.to_bits(),
        "streamed chunks must be value-identical"
    );
    assert_eq!(mem.centroids, ooc.centroids);
    let _ = std::fs::remove_file(&p);
}

#[test]
fn corrupted_block_is_isolated_and_named() {
    let data = blobs(4_000, 4, 3, 5);
    let p = tmp("corrupt.bmx");
    let opts = StoreOptions { block_rows: 256, codec: Codec::Shuffle, ..StoreOptions::default() };
    copy_to_store(&data, &p, opts).unwrap();
    let clean = BlockStore::open(&p).unwrap();
    assert_eq!(clean.blocks(), 16);
    assert_eq!(clean.verify_all(4).unwrap().blocks, 16);
    let (lo, hi) = clean.block_byte_range(9);
    drop(clean);

    let mut bytes = std::fs::read(&p).unwrap();
    let mid = ((lo + hi) / 2) as usize;
    bytes[mid] ^= 0x80;
    std::fs::write(&p, &bytes).unwrap();

    // Open stays O(index) — the corruption is not in the index.
    let store = BlockStore::open(&p).unwrap();
    let err = store.verify_all(4).unwrap_err().to_string();
    assert!(err.contains("block 9"), "verify must name block 9: {err}");
    assert!(err.contains("checksum"), "diagnosis must say why: {err}");

    // Rows in other blocks read fine (integrity is per touched block) …
    let mut out = vec![0f32; 256 * 4];
    store.read_rows(0, &mut out);
    assert_eq!(out, &data.points()[..256 * 4]);
    // … while touching block 9 (rows 2304..2560) panics, naming it.
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut row = vec![0f32; 4];
        store.read_rows(2_400, &mut row);
    }))
    .unwrap_err();
    let msg = caught
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| "non-string panic".into());
    assert!(msg.contains("block 9"), "read panic must name block 9: {msg}");
    let _ = std::fs::remove_file(&p);
}

#[test]
fn legacy_v1_v2_open_paths_regression() {
    let data = blobs(500, 3, 3, 6);

    // v2: still written by save_bmx, still loads via mmap/buffered, and
    // the block backend refuses it with a reconversion hint.
    let v2 = tmp("legacy_v2.bmx");
    save_bmx(&data, &v2).unwrap();
    assert_eq!(bmx_version(&v2).unwrap(), 2);
    for backend in [DataBackend::Mmap, DataBackend::Buffered, DataBackend::InMemory] {
        let src = loader::open_source(&v2, backend).unwrap();
        let mut all = vec![0f32; 500 * 3];
        src.read_rows(0, &mut all);
        assert_eq!(all, data.points(), "{backend:?}");
    }
    let err = loader::open_source(&v2, DataBackend::Block).unwrap_err().to_string();
    assert!(err.contains("v2") && err.contains("convert"), "hint missing: {err}");

    // v1: hand-built 16-byte header, still loads.
    let v1 = tmp("legacy_v1.bmx");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"BMX1");
    bytes.extend_from_slice(&(data.m() as u64).to_le_bytes());
    bytes.extend_from_slice(&(data.n() as u32).to_le_bytes());
    for &v in data.points() {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(&v1, &bytes).unwrap();
    assert_eq!(bmx_version(&v1).unwrap(), 1);
    let src = loader::open_source(&v1, DataBackend::Buffered).unwrap();
    let mut all = vec![0f32; 500 * 3];
    src.read_rows(0, &mut all);
    assert_eq!(all, data.points());

    // v3 through the generic mmap/buffered/mem backends (magic sniffing).
    let v3 = tmp("legacy_v3.bmx");
    copy_to_store(&data, &v3, StoreOptions::default()).unwrap();
    for backend in [
        DataBackend::Mmap,
        DataBackend::Buffered,
        DataBackend::Block,
        DataBackend::InMemory,
    ] {
        let src = loader::open_source(&v3, backend).unwrap();
        let mut all = vec![0f32; 500 * 3];
        src.read_rows(0, &mut all);
        assert_eq!(all, data.points(), "{backend:?}");
    }

    for p in [v1, v2, v3] {
        let _ = std::fs::remove_file(&p);
    }
}

#[test]
fn f16_store_clusters_with_bounded_quantisation_error() {
    // f16 is the lossy variant: the pipeline must still run end-to-end,
    // and on well-separated blobs the objective must stay close to the
    // exact run (quantisation noise ≪ cluster spread).
    let data = blobs(10_000, 4, 3, 7);
    let p = tmp("f16_cluster.bmx");
    let opts = StoreOptions { dtype: Dtype::F16, codec: Codec::Lz, ..StoreOptions::default() };
    copy_to_store(&data, &p, opts).unwrap();
    let store = BlockStore::open(&p).unwrap();
    let exact = BigMeans::new(sequential_cfg(3, 1024, 10)).run(&data).unwrap();
    let quant = BigMeans::new(sequential_cfg(3, 1024, 10)).run(&store).unwrap();
    assert!(quant.objective.is_finite());
    let rel = (quant.objective - exact.objective).abs() / exact.objective.max(1e-12);
    assert!(
        rel < 0.05,
        "f16 objective drifted {rel:.4} from exact ({} vs {})",
        quant.objective,
        exact.objective
    );
    let _ = std::fs::remove_file(&p);
}

// ---------------------------------------------------------------------------
// Decode-free f16 compute (dtype = f16 × codec = none × mmap backing).
// ---------------------------------------------------------------------------

#[test]
fn fused_f16_pipeline_bit_identical_to_decoded_across_engines_and_codecs() {
    // The fused reader widens raw f16 halfwords per element with the same
    // conversion the decode-to-slab path uses, so a full Big-means run
    // over the fused store must reproduce the decode-then-f32 run bit for
    // bit — for every kernel engine, on a shape with masked SIMD tails
    // (n % 32 != 0) and a single-row final block (10241 = 40·256 + 1).
    // The lz store decodes to the same values (the codec is lossless over
    // the f16 payload) but can never fuse, covering the codec axis too.
    // CI runs this binary under BIGMEANS_ISA=scalar and =auto, which adds
    // the ISA axis on top.
    let data = blobs(10_241, 7, 4, 31);
    let p = tmp("fused_engines.bmx");
    let base = StoreOptions {
        block_rows: 256,
        dtype: Dtype::F16,
        codec: Codec::None,
        ..StoreOptions::default()
    };
    copy_to_store(&data, &p, base).unwrap();
    let fused = BlockStore::open(&p).unwrap();
    if !fused.is_mmap() {
        return; // the fused path needs mmap backing on this target
    }
    assert!(fused.fused_f16_active());
    let decoded = BlockStore::open(&p).unwrap();
    decoded.set_fused_f16(false);
    assert!(!decoded.fused_f16_active());
    let p_lz = tmp("fused_engines_lz.bmx");
    copy_to_store(&data, &p_lz, StoreOptions { codec: Codec::Lz, ..base }).unwrap();
    let via_lz = BlockStore::open(&p_lz).unwrap();
    assert!(!via_lz.fused_f16_active(), "a compressed store must never fuse");
    for kind in [
        KernelEngineKind::Panel,
        KernelEngineKind::Bounded,
        KernelEngineKind::Elkan,
        KernelEngineKind::Hybrid,
    ] {
        let run = |src: &dyn DataSource| {
            BigMeans::new(sequential_cfg(4, 1024, 12).with_kernel(kind)).run(src).unwrap()
        };
        let a = run(&fused);
        assert_bit_identical(&a, &run(&decoded), &format!("fused vs decoded ({kind:?})"));
        assert_bit_identical(&a, &run(&via_lz), &format!("fused vs f16/lz ({kind:?})"));
    }
    // The fused store served every read without the decoded-f32 cache.
    assert_eq!(fused.cache_stats(), (0, 0), "fused reads must bypass the LRU");
    let (hits, misses) = decoded.cache_stats();
    assert!(hits + misses > 0, "decoded comparator must have used the cache");
    let _ = std::fs::remove_file(&p);
    let _ = std::fs::remove_file(&p_lz);
}

#[test]
fn fused_f16_awkward_shapes_read_bit_identical_to_decode() {
    // Raw read parity on the shapes most likely to trip a vector tail:
    // n = 33 (one full 32-lane tile + 1-element scalar tail per row),
    // single-row blocks (block_rows = 1), and a one-row store.
    for (m, n, block_rows) in [(257usize, 33usize, 64usize), (17, 33, 1), (1, 5, 256)] {
        let d = blobs(m, n, 3, 32 + m as u64);
        let p = tmp(&format!("fused_tail_{m}_{n}_{block_rows}.bmx"));
        let opts = StoreOptions {
            block_rows,
            dtype: Dtype::F16,
            codec: Codec::None,
            ..StoreOptions::default()
        };
        copy_to_store(&d, &p, opts).unwrap();
        let fused = BlockStore::open(&p).unwrap();
        if !fused.is_mmap() {
            return;
        }
        let decoded = BlockStore::open(&p).unwrap();
        decoded.set_fused_f16(false);
        let label = format!("m={m} n={n} block_rows={block_rows}");
        let mut a = vec![0f32; m * n];
        let mut b = vec![0f32; m * n];
        fused.read_rows(0, &mut a);
        decoded.read_rows(0, &mut b);
        assert_eq!(a, b, "{label}: full read");
        let idx: Vec<usize> = (0..m).rev().step_by(3).collect();
        let mut ga = vec![0f32; idx.len() * n];
        let mut gb = vec![0f32; idx.len() * n];
        fused.sample_rows(&idx, &mut ga);
        decoded.sample_rows(&idx, &mut gb);
        assert_eq!(ga, gb, "{label}: scattered gather");
        let _ = std::fs::remove_file(&p);
    }
}

// ---------------------------------------------------------------------------
// Hierarchical pruning: the block-pruned + double-buffered final pass.
// ---------------------------------------------------------------------------

/// Blobs *grouped by cluster* (rows sorted so fixed-size store blocks are
/// pure single-cluster boxes) — the layout where block-level pruning
/// fires. `per` rows per cluster, centers far apart, spread tiny.
fn grouped_blobs(k_true: usize, per: usize, n: usize, seed: u64) -> Dataset {
    use bigmeans::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let centers: Vec<f32> = (0..k_true * n).map(|_| rng.f32() * 200.0 - 100.0).collect();
    let mut pts = Vec::with_capacity(k_true * per * n);
    for c in 0..k_true {
        for _ in 0..per {
            for d in 0..n {
                pts.push(centers[c * n + d] + 0.05 * rng.gaussian() as f32);
            }
        }
    }
    Dataset::from_vec("grouped", pts, k_true * per, n)
}

fn assert_same_final(a: &BigMeansResult, b: &BigMeansResult, label: &str) {
    assert_eq!(
        a.objective.to_bits(),
        b.objective.to_bits(),
        "{label}: objectives differ: {} vs {}",
        a.objective,
        b.objective
    );
    assert_eq!(a.centroids, b.centroids, "{label}: centroids differ");
    assert_eq!(a.assignment, b.assignment, "{label}: assignments differ");
}

#[test]
fn pruned_final_pass_bit_identical_across_dtype_codec() {
    // For every dtype × codec: a store with summaries (pruned final pass)
    // must reproduce the same store without summaries (unpruned) bit for
    // bit — labels, objective, centroids — while skipping blocks and
    // distance evaluations. Lossless dtypes must also match the in-memory
    // run exactly.
    let data = grouped_blobs(4, 1024, 5, 21);
    let run = |src: &dyn DataSource| {
        BigMeans::new(sequential_cfg(4, 512, 25)).run(src).unwrap()
    };
    let mem = run(&data);
    for dtype in [Dtype::F32, Dtype::F64, Dtype::F16] {
        for codec in [Codec::None, Codec::Shuffle, Codec::Lz] {
            let label = format!("{}/{}", dtype.name(), codec.name());
            let p_sum = tmp(&format!("prune_sum_{}_{}.bmx", dtype.name(), codec.name()));
            let p_plain = tmp(&format!("prune_plain_{}_{}.bmx", dtype.name(), codec.name()));
            let base = StoreOptions { block_rows: 256, dtype, codec, ..StoreOptions::default() };
            copy_to_store(&data, &p_sum, base).unwrap();
            copy_to_store(&data, &p_plain, StoreOptions { summaries: false, ..base }).unwrap();
            let pruned = run(&BlockStore::open(&p_sum).unwrap());
            let plain = run(&BlockStore::open(&p_plain).unwrap());
            assert_same_final(&pruned, &plain, &label);
            assert!(
                pruned.counters.pruned_blocks > 0,
                "{label}: no blocks pruned on a grouped dataset"
            );
            assert_eq!(plain.counters.pruned_blocks, 0, "{label}");
            assert!(
                pruned.counters.distance_evals < plain.counters.distance_evals,
                "{label}: pruning saved nothing ({} vs {})",
                pruned.counters.distance_evals,
                plain.counters.distance_evals
            );
            if dtype != Dtype::F16 {
                assert_same_final(&pruned, &mem, &format!("{label} vs mem"));
            }
            let _ = std::fs::remove_file(&p_sum);
            let _ = std::fs::remove_file(&p_plain);
        }
    }
}

#[test]
fn crafted_fully_prunable_dataset_skips_every_block() {
    // Block-pure, widely separated, tiny-spread blobs with k = k_true:
    // once the search finds all four centers, *every* block's bounding box
    // is wholly owned — pruned_blocks must equal the block count, and the
    // result must still match the in-memory run bit for bit.
    let data = grouped_blobs(4, 1024, 4, 22);
    let p = tmp("prune_all.bmx");
    let opts = StoreOptions { block_rows: 256, ..StoreOptions::default() };
    copy_to_store(&data, &p, opts).unwrap();
    let store = BlockStore::open(&p).unwrap();
    assert_eq!(store.blocks(), 16);
    let run = |src: &dyn DataSource| {
        BigMeans::new(sequential_cfg(4, 512, 30)).run(src).unwrap()
    };
    let mem = run(&data);
    let pruned = run(&store);
    assert_same_final(&mem, &pruned, "mem vs fully-pruned block store");
    assert_eq!(
        pruned.counters.pruned_blocks, 16,
        "every block must be owned by one centroid"
    );
    // Final pass cost collapses from m·k to m evaluations: the pruned run
    // must save (k−1)·m of the final pass (the chunk search is shared).
    assert_eq!(
        pruned.counters.pruned_evals,
        (data.m() as u64) * 3,
        "owned rows must avoid exactly k−1 evals each"
    );
    assert_eq!(mem.counters.pruned_blocks, 0);
    let _ = std::fs::remove_file(&p);
}

#[test]
fn pruned_parallel_final_pass_matches_resident_parallel() {
    // Same thread count on both sides, so the chunk searches are
    // bit-reproducible and reach the same incumbent; the final pass then
    // runs resident + sharded on mem vs pruned + double-buffered on the
    // block store — per-point arithmetic and the row-ordered objective
    // make them bit-identical despite completely different execution
    // shapes.
    let data = grouped_blobs(3, 2048, 4, 23);
    let p = tmp("prune_threads.bmx");
    copy_to_store(&data, &p, StoreOptions { block_rows: 512, ..StoreOptions::default() })
        .unwrap();
    let store = BlockStore::open(&p).unwrap();
    let run = |src: &dyn DataSource| {
        let mut cfg = BigMeansConfig::new(3, 512)
            .with_stop(StopCondition::MaxChunks(15))
            .with_parallel(ParallelMode::InnerParallel)
            .with_seed(42);
        cfg.threads = 4;
        BigMeans::new(cfg).run(src).unwrap()
    };
    let mem = run(&data);
    let pruned = run(&store);
    assert_same_final(&mem, &pruned, "resident-parallel vs pruned-double-buffered");
    assert!(pruned.counters.pruned_blocks > 0);
    assert_eq!(mem.counters.pruned_blocks, 0);
    let _ = std::fs::remove_file(&p);
}

#[test]
fn add_summaries_retrofits_and_verify_checks_consistency() {
    use bigmeans::store::add_summaries;
    use bigmeans::util::hash::crc32;

    let data = grouped_blobs(3, 512, 4, 24);
    let p = tmp("retrofit.bmx");
    let opts = StoreOptions {
        block_rows: 128,
        codec: Codec::Lz,
        summaries: false,
        ..StoreOptions::default()
    };
    copy_to_store(&data, &p, opts).unwrap();
    let before = BlockStore::open(&p).unwrap();
    assert!(!before.has_summaries());
    let run = |src: &dyn DataSource| {
        BigMeans::new(sequential_cfg(3, 256, 15)).run(src).unwrap()
    };
    let unpruned = run(&before);
    assert_eq!(unpruned.counters.pruned_blocks, 0);
    drop(before);

    // Retrofit in place (decode-only), then the same run prunes — and
    // stays bit-identical.
    assert!(add_summaries(&p, 2).unwrap());
    let after = BlockStore::open(&p).unwrap();
    assert!(after.has_summaries());
    after.verify_all(2).unwrap();
    let pruned = run(&after);
    assert_same_final(&unpruned, &pruned, "retrofit");
    assert!(pruned.counters.pruned_blocks > 0);
    drop(after);
    // Idempotent: a second retrofit is a no-op.
    assert!(!add_summaries(&p, 2).unwrap());

    // Forge a *CRC-consistent* but wrong summary value: verify must catch
    // the inconsistency against the decoded block.
    let mut bytes = std::fs::read(&p).unwrap();
    let summary_off = u64::from_le_bytes(bytes[36..44].try_into().unwrap()) as usize;
    bytes[summary_off..summary_off + 4].copy_from_slice(&f32::MIN.to_le_bytes());
    let fresh_crc = crc32(&bytes[summary_off..]);
    bytes[44..48].copy_from_slice(&fresh_crc.to_le_bytes());
    std::fs::write(&p, &bytes).unwrap();
    let forged = BlockStore::open(&p).unwrap(); // CRC passes…
    let err = forged.verify_all(2).unwrap_err().to_string();
    assert!(
        err.contains("summary mismatch") && err.contains("block 0"),
        "verify must flag the stale summary: {err}"
    );
    let _ = std::fs::remove_file(&p);
}
