//! Property tests for the observability subsystem: log2-histogram
//! quantile bounds on adversarial distributions, exposition lint
//! round-trips over real `Registry::render` output, trace-document
//! shape, flight-recorder boundedness, and the load-bearing contract
//! that enabling metrics, tracing, or the recorder never changes a
//! clustering run's bits.

use std::sync::Mutex;
use std::time::Duration;

use bigmeans::coordinator::config::{BigMeansConfig, ParallelMode, StopCondition};
use bigmeans::data::Synth;
use bigmeans::obs::{self, lint, Log2Histogram, Registry};
use bigmeans::util::json::Json;
use bigmeans::BigMeans;

/// The tracer and the `obs::metrics()` registry are process singletons;
/// tests that flip their enabled flags serialize on this lock so the
/// harness's parallel test threads cannot observe each other's state.
static GLOBAL_OBS: Mutex<()> = Mutex::new(());

fn lock_global() -> std::sync::MutexGuard<'static, ()> {
    GLOBAL_OBS.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// Log2Histogram quantile bounds.
//
// The estimator returns the upper bound of the bucket holding the target
// rank, so for any sample set it must bracket the true quantile from
// above by at most the bucket width: true <= est <= 2 * max(true, 1µs).
// ---------------------------------------------------------------------------

/// True quantile (seconds) using the same rank rule as the estimator:
/// the element at rank `ceil(q * total)` (1-based) of the sorted samples.
fn true_quantile_secs(samples_us: &[u64], q: f64) -> f64 {
    let mut sorted = samples_us.to_vec();
    sorted.sort_unstable();
    let total = sorted.len() as u64;
    let target = ((q * total as f64).ceil() as u64).clamp(1, total);
    sorted[(target - 1) as usize] as f64 * 1e-6
}

fn assert_quantile_bounds(name: &str, samples_us: &[u64]) {
    let h = Log2Histogram::new();
    for &us in samples_us {
        h.record_us(us);
    }
    assert_eq!(h.total(), samples_us.len() as u64);
    for &q in &[0.50, 0.95, 0.99] {
        let truth = true_quantile_secs(samples_us, q);
        let est = h.percentile_secs(q);
        assert!(
            truth <= est && est <= 2.0 * truth.max(1e-6),
            "{name}: q={q} true {truth:.3e} est {est:.3e} violates \
             true <= est <= 2*max(true, 1e-6)"
        );
    }
}

#[test]
fn prop_histogram_quantiles_all_one_bucket() {
    // Every sample identical: the estimator must report exactly the
    // bucket upper bound of that one value at every quantile.
    for &v in &[0u64, 1, 7, 4096, 1_000_000] {
        let samples = vec![v; 257];
        assert_quantile_bounds("all-one-bucket", &samples);
        let h = Log2Histogram::new();
        for &us in &samples {
            h.record_us(us);
        }
        assert_eq!(h.percentile_secs(0.5), h.percentile_secs(0.999));
    }
}

#[test]
fn prop_histogram_quantiles_bimodal() {
    // Two widely separated modes: the p50/p99 split must land on the
    // correct mode for several mixture ratios, including the adversarial
    // 50/50 split where the median sits exactly on the mode boundary.
    for &(lo_count, hi_count) in &[(999usize, 1usize), (500, 500), (1, 999), (90, 10)] {
        let mut samples = vec![3u64; lo_count];
        samples.extend(std::iter::repeat(1_000_000u64).take(hi_count));
        assert_quantile_bounds("bimodal", &samples);
    }
    // With 1% of mass in the slow mode, p50 is fast and p99+ is slow.
    let mut samples = vec![3u64; 990];
    samples.extend(std::iter::repeat(1_000_000u64).take(10));
    let h = Log2Histogram::new();
    for &us in &samples {
        h.record_us(us);
    }
    assert!(h.percentile_secs(0.50) <= 4e-6);
    assert!(h.percentile_secs(0.995) >= 1.0);
}

#[test]
fn prop_histogram_quantiles_monotone_ramp() {
    // A linear ramp exercises every low bucket and checks the estimate
    // stays monotone in q (a cumulative-count scan must never regress).
    let samples: Vec<u64> = (0..10_000u64).collect();
    assert_quantile_bounds("ramp", &samples);
    let h = Log2Histogram::new();
    for &us in &samples {
        h.record_us(us);
    }
    let mut prev = 0.0f64;
    for i in 1..=100 {
        let est = h.percentile_secs(i as f64 / 100.0);
        assert!(est >= prev, "quantile estimate regressed at q={}", i as f64 / 100.0);
        prev = est;
    }
}

// ---------------------------------------------------------------------------
// Exposition lint over real registry output.
// ---------------------------------------------------------------------------

/// A local registry shaped like the process one: labeled counters, a
/// gauge, and a multi-series histogram.
fn populated_registry() -> Registry {
    let reg = Registry::new();
    reg.enable();
    reg.counter("t_distance_evals_total", "evals", &[("engine", "panel"), ("isa", "scalar")])
        .add(12);
    reg.counter("t_distance_evals_total", "evals", &[("engine", "elkan"), ("isa", "scalar")])
        .add(5);
    reg.gauge("t_resident_bytes", "resident", &[]).set(1.5e6);
    let h = reg.histogram("t_request_seconds", "latency", &[("op", "assign")]);
    h.observe(Duration::from_micros(3));
    h.observe(Duration::from_micros(900));
    reg.histogram("t_request_seconds", "latency", &[("op", "score")])
        .observe(Duration::from_micros(40));
    reg
}

#[test]
fn rendered_exposition_passes_lint() {
    let reg = populated_registry();
    let e = lint::lint_exposition(&reg.render()).expect("render must lint clean");
    assert_eq!(e.families.len(), 3);
    assert_eq!(e.families["t_distance_evals_total"].kind, "counter");
    assert_eq!(e.families["t_request_seconds"].kind, "histogram");
    assert!(e.samples >= 5);
}

#[test]
fn rendered_expositions_stay_monotone_across_scrapes() {
    let reg = populated_registry();
    let first = lint::lint_exposition(&reg.render()).unwrap();
    // More traffic between scrapes: counters and buckets only grow.
    reg.counter("t_distance_evals_total", "evals", &[("engine", "panel"), ("isa", "scalar")])
        .add(100);
    reg.histogram("t_request_seconds", "latency", &[("op", "assign")])
        .observe(Duration::from_micros(7));
    let second = lint::lint_exposition(&reg.render()).unwrap();
    let checked = lint::check_monotone(&first, &second).expect("no counter may regress");
    assert!(checked > 0, "monotone check must cover at least one series");
    // The reverse direction must be flagged as a regression.
    assert!(lint::check_monotone(&second, &first).unwrap_err().contains("backwards"));
}

#[test]
fn lint_rejects_adversarial_documents() {
    let good = populated_registry().render();
    // Duplicate TYPE line for an existing family.
    let dup = format!(
        "{good}# HELP t_resident_bytes resident\n# TYPE t_resident_bytes gauge\nt_resident_bytes 2\n"
    );
    assert!(lint::lint_exposition(&dup).unwrap_err().contains("duplicate"));
    // A sample with no announced family.
    let orphan = format!("{good}mystery_total 1\n");
    assert!(lint::lint_exposition(&orphan).unwrap_err().contains("TYPE"));
}

// ---------------------------------------------------------------------------
// Global tracer: one test, because the tracer is a process singleton.
// ---------------------------------------------------------------------------

#[test]
fn global_tracer_buffers_renders_and_clears() {
    let _g = lock_global();
    let tracer = obs::tracer();
    tracer.disable_and_clear();

    // Disabled spans are free: nothing buffers.
    drop(tracer.span("shot", "noop"));
    assert_eq!(tracer.buffered().0, 0);

    tracer.enable_unsinked();
    {
        let _outer = tracer.span("shot", "chunk");
        drop(tracer.span("shot.sample", "draw"));
        drop(tracer.span("shot.lloyd", "iterate"));
        drop(tracer.span_dyn("tuner.pull", "0.5x/panel".to_string()));
    }
    let (buffered, dropped) = tracer.buffered();
    assert_eq!(buffered, 4);
    assert_eq!(dropped, 0);

    // Render drains the shards into a Chrome trace-event document.
    let doc: Json = tracer.render();
    let events = doc
        .get("traceEvents")
        .and_then(|j| j.as_arr())
        .expect("traceEvents array");
    assert_eq!(events.len(), 4);
    let mut cats: Vec<&str> = Vec::new();
    for ev in events {
        assert_eq!(ev.get("ph").and_then(|j| j.as_str()), Some("X"));
        assert!(ev.get("ts").and_then(|j| j.as_f64()).is_some());
        assert!(ev.get("dur").and_then(|j| j.as_f64()).is_some());
        assert!(ev.get("pid").and_then(|j| j.as_f64()).is_some());
        assert!(ev.get("tid").and_then(|j| j.as_f64()).is_some());
        cats.push(ev.get("cat").and_then(|j| j.as_str()).expect("cat string"));
    }
    cats.sort_unstable();
    assert_eq!(cats, ["shot", "shot.lloyd", "shot.sample", "tuner.pull"]);
    assert_eq!(tracer.buffered().0, 0, "render drains the buffers");

    // The document round-trips through the JSON parser Perfetto-style.
    let reparsed = Json::parse(&doc.to_string()).expect("trace document reparses");
    assert!(reparsed.get("traceEvents").is_some());

    // The ring cap drops instead of growing without bound.
    for _ in 0..(obs::trace::SHARD_CAP + 10) {
        drop(tracer.span("shot", "flood"));
    }
    let (buffered, dropped) = tracer.buffered();
    assert_eq!(buffered, obs::trace::SHARD_CAP);
    assert_eq!(dropped, 10);

    tracer.disable_and_clear();
    assert_eq!(tracer.buffered(), (0, 0));
    drop(tracer.span("shot", "after-clear"));
    assert_eq!(tracer.buffered().0, 0);
}

// ---------------------------------------------------------------------------
// Bit-identicality: observers never participate.
// ---------------------------------------------------------------------------

#[test]
fn metrics_and_tracing_do_not_change_clustering_bits() {
    let _g = lock_global();
    let data = Synth::GaussianMixture {
        m: 12_000,
        n: 6,
        k_true: 7,
        spread: 0.3,
        box_half_width: 25.0,
    }
    .generate("obs-ab", 17);
    let run = || {
        let cfg = BigMeansConfig::new(7, 1024)
            .with_stop(StopCondition::MaxChunks(20))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(41);
        BigMeans::new(cfg).run(&data).unwrap()
    };

    obs::tracer().disable_and_clear();
    obs::metrics().disable();
    let plain = run();

    obs::metrics().enable();
    obs::register_core("panel", "scalar");
    obs::tracer().enable_unsinked();
    let observed = run();
    let (spans, _) = obs::tracer().buffered();
    obs::tracer().disable_and_clear();
    obs::metrics().disable();

    assert!(spans > 0, "an observed run must actually emit spans");
    assert_eq!(
        plain.objective.to_bits(),
        observed.objective.to_bits(),
        "objective changed under observation: {} vs {}",
        plain.objective,
        observed.objective
    );
    assert_eq!(plain.assignment, observed.assignment);
    assert_eq!(plain.centroids, observed.centroids);
    assert_eq!(plain.counters.distance_evals, observed.counters.distance_evals);
}

// ---------------------------------------------------------------------------
// Flight recorder: bounded memory, and the same never-participate contract.
// ---------------------------------------------------------------------------

#[test]
fn recorder_memory_stays_bounded_under_span_floods() {
    let _g = lock_global();
    let rec = obs::recorder();
    obs::tracer().disable_and_clear();
    obs::metrics().disable();
    rec.disable_and_clear();
    rec.enable_unsinked();

    // Far more span completions than the ring holds, from several threads
    // at once. The tracer proper stays off: spans reach the recorder
    // through the tracer's tap without buffering any shard entries.
    let per_thread = bigmeans::obs::recorder::SPAN_RING_CAP * 10;
    std::thread::scope(|scope| {
        for _ in 0..4 {
            scope.spawn(move || {
                for _ in 0..per_thread {
                    drop(obs::tracer().span("shot", "flood"));
                }
            });
        }
    });
    assert_eq!(obs::tracer().buffered().0, 0, "tracer-off spans must not buffer");

    let doc = rec.dump_json("property-test", None);
    let spans = doc.get("spans").and_then(|j| j.as_arr()).expect("spans array");
    assert!(
        spans.len() <= bigmeans::obs::recorder::SPAN_RING_CAP,
        "span ring exceeded its cap: {}",
        spans.len()
    );
    let recorded = doc.get("spans_recorded").and_then(|j| j.as_f64()).expect("spans_recorded");
    assert!(
        recorded >= (4 * per_thread) as f64 * 0.99,
        "fetch_add head must count (almost) every push, got {recorded}"
    );

    // Warn-level log records ride a second bounded ring.
    for i in 0..(bigmeans::obs::recorder::LOG_RING_CAP + 32) {
        bigmeans::log_warn!("prop.recorder", "flood record {i}");
    }
    let doc = rec.dump_json("property-test", None);
    let logs = doc.get("logs").and_then(|j| j.as_arr()).expect("logs array");
    assert!(!logs.is_empty(), "warn records must reach the recorder");
    assert!(logs.len() <= bigmeans::obs::recorder::LOG_RING_CAP);

    // The document is well-formed JSON with the versioned schema tag.
    let text = doc.to_string();
    let back = Json::parse(&text).expect("diagnostics document reparses");
    assert_eq!(
        back.get("schema").and_then(|j| j.as_str()),
        Some(bigmeans::obs::recorder::DIAGNOSTICS_SCHEMA)
    );

    rec.disable_and_clear();
    drop(obs::tracer().span("shot", "after-clear"));
    let cleared = rec.dump_json("property-test", None);
    assert_eq!(cleared.get("spans").and_then(|j| j.as_arr()).map(|a| a.len()), Some(0));
}

#[test]
fn flight_recorder_does_not_change_clustering_bits() {
    let _g = lock_global();
    let data = Synth::GaussianMixture {
        m: 12_000,
        n: 6,
        k_true: 7,
        spread: 0.3,
        box_half_width: 25.0,
    }
    .generate("recorder-ab", 23);
    let run = || {
        let cfg = BigMeansConfig::new(7, 1024)
            .with_stop(StopCondition::MaxChunks(20))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(43);
        BigMeans::new(cfg).run(&data).unwrap()
    };

    obs::tracer().disable_and_clear();
    obs::metrics().disable();
    obs::recorder().disable_and_clear();
    let plain = run();

    obs::recorder().enable_unsinked();
    let observed = run();
    let doc = obs::recorder().dump_json("ab-test", None);
    obs::recorder().disable_and_clear();

    let spans = doc.get("spans").and_then(|j| j.as_arr()).map(|a| a.len()).unwrap_or(0);
    assert!(spans > 0, "a recorded run must actually capture spans");
    assert_eq!(
        plain.objective.to_bits(),
        observed.objective.to_bits(),
        "objective changed under the flight recorder: {} vs {}",
        plain.objective,
        observed.objective
    );
    assert_eq!(plain.assignment, observed.assignment);
    assert_eq!(plain.centroids, observed.centroids);
    assert_eq!(plain.counters.distance_evals, observed.counters.distance_evals);
}
