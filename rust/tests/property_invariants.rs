//! Property-based tests over the kernel and coordinator invariants, driven
//! by the in-crate `util::prop` shrinking harness (no proptest offline).

use bigmeans::coordinator::config::{BigMeansConfig, ParallelMode, StopCondition};
use bigmeans::coordinator::sampler::ChunkSampler;
use bigmeans::data::bmx::{save_bmx, BmxSource};
use bigmeans::kernels;
use bigmeans::metrics::Counters;
use bigmeans::util::prop::{check, ClusterProblem, ClusterProblemGen};
use bigmeans::util::rng::Rng;
use bigmeans::util::threadpool::ThreadPool;
use bigmeans::{BigMeans, Dataset};

fn seed_centroids(p: &ClusterProblem, rng: &mut Rng) -> Vec<f32> {
    let idx = rng.sample_indices(p.m, p.k);
    let mut c = Vec::with_capacity(p.k * p.n);
    for &i in &idx {
        c.extend_from_slice(&p.points[i * p.n..(i + 1) * p.n]);
    }
    c
}

#[test]
fn prop_assignment_partitions_points() {
    // Assignment invariants for arbitrary shapes/values: every point gets a
    // valid label, counts partition m, objective equals Σ mins.
    check(1, 120, &ClusterProblemGen::default(), |p| {
        let mut rng = Rng::new(7);
        let c = seed_centroids(p, &mut rng);
        let mut counters = Counters::new();
        let out = kernels::assign_accumulate(&p.points, &c, p.m, p.n, p.k, &mut counters);
        let labels_ok = out.labels.iter().all(|&l| (l as usize) < p.k);
        let counts_ok = out.counts.iter().sum::<u64>() == p.m as u64;
        let sum_mins: f64 = out.mins.iter().map(|&x| x as f64).sum();
        let obj_ok = (out.objective - sum_mins).abs() <= 1e-3 * sum_mins.max(1.0);
        let evals_ok = counters.distance_evals == (p.m * p.k) as u64;
        labels_ok && counts_ok && obj_ok && evals_ok
    });
}

#[test]
fn prop_assignment_chooses_true_nearest() {
    // Cross-check blocked panel argmin against the direct per-point path.
    check(2, 80, &ClusterProblemGen::default(), |p| {
        let mut rng = Rng::new(11);
        let c = seed_centroids(p, &mut rng);
        let mut c1 = Counters::new();
        let mut c2 = Counters::new();
        let fused = kernels::assign_accumulate(&p.points, &c, p.m, p.n, p.k, &mut c1);
        let (direct, _) = kernels::assign_only(&p.points, &c, p.m, p.n, p.k, &mut c2);
        fused.labels == direct
    });
}

#[test]
fn prop_lloyd_never_increases_objective() {
    // Lloyd monotonicity: the converged objective never exceeds the seed's.
    check(3, 60, &ClusterProblemGen::default(), |p| {
        let mut rng = Rng::new(13);
        let c = seed_centroids(p, &mut rng);
        let mut counters = Counters::new();
        let before = kernels::objective(&p.points, &c, p.m, p.n, p.k, &mut counters);
        let r = kernels::lloyd(
            &p.points,
            &c,
            p.m,
            p.n,
            p.k,
            Default::default(),
            None,
            &mut counters,
        );
        r.objective <= before * (1.0 + 1e-5) + 1e-4
    });
}

#[test]
fn prop_update_centroids_are_means() {
    // After one assignment+update, each non-degenerate centroid is the mean
    // of its assigned points.
    check(4, 60, &ClusterProblemGen::default(), |p| {
        let mut rng = Rng::new(17);
        let c0 = seed_centroids(p, &mut rng);
        let mut counters = Counters::new();
        let out = kernels::assign_accumulate(&p.points, &c0, p.m, p.n, p.k, &mut counters);
        let mut c = c0.clone();
        kernels::update_centroids(&out.sums, &out.counts, &mut c, p.k, p.n);
        for j in 0..p.k {
            if out.counts[j] == 0 {
                // degenerate: untouched
                if c[j * p.n..(j + 1) * p.n] != c0[j * p.n..(j + 1) * p.n] {
                    return false;
                }
                continue;
            }
            // recompute mean directly
            let mut mean = vec![0f64; p.n];
            let mut cnt = 0u64;
            for (i, &l) in out.labels.iter().enumerate() {
                if l as usize == j {
                    cnt += 1;
                    for t in 0..p.n {
                        mean[t] += p.points[i * p.n + t] as f64;
                    }
                }
            }
            if cnt != out.counts[j] {
                return false;
            }
            for t in 0..p.n {
                let want = (mean[t] / cnt as f64) as f32;
                let got = c[j * p.n + t];
                if (want - got).abs() > 1e-2 * want.abs().max(1.0) {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_kmeanspp_selects_distinct_data_points_when_possible() {
    check(5, 60, &ClusterProblemGen::default(), |p| {
        let mut rng = Rng::new(19);
        let mut counters = Counters::new();
        let c = kernels::kmeanspp(&p.points, p.m, p.n, p.k, 1, &mut rng, &mut counters);
        // every centroid is a data point
        for j in 0..p.k {
            let cj = &c[j * p.n..(j + 1) * p.n];
            let found = (0..p.m).any(|i| {
                p.points[i * p.n..(i + 1) * p.n]
                    .iter()
                    .zip(cj)
                    .all(|(a, b)| a == b)
            });
            if !found {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_bigmeans_total_counts_and_finite_objective() {
    // Coordinator-level invariants on random problems: runs complete, the
    // assignment covers all m points, counters are consistent.
    let gen = ClusterProblemGen {
        m_range: (50, 400),
        n_range: (1, 8),
        k_max: 6,
        coord_range: (-50.0, 50.0),
    };
    check(6, 25, &gen, |p| {
        let data = bigmeans::Dataset::from_vec("prop", p.points.clone(), p.m, p.n);
        let cfg = BigMeansConfig::new(p.k, (p.m / 2).max(p.k))
            .with_stop(StopCondition::MaxChunks(5))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(23);
        let Ok(r) = BigMeans::new(cfg).run(&data) else {
            return false;
        };
        r.objective.is_finite()
            && r.assignment.len() == p.m
            && r.assignment.iter().all(|&a| (a as usize) < p.k)
            && r.counters.chunks == 5
    });
}

#[test]
fn prop_lloyd_objective_non_increasing_per_iteration() {
    // Stronger than end-to-end monotonicity: *every* assignment+update
    // iteration must not increase the objective (Lloyd's classic descent
    // property), checked on random problems with a small fp tolerance.
    check(8, 50, &ClusterProblemGen::default(), |p| {
        let mut rng = Rng::new(29);
        let mut c = seed_centroids(p, &mut rng);
        let mut counters = Counters::new();
        let mut prev = f64::INFINITY;
        for _ in 0..6 {
            let out = kernels::assign_accumulate(&p.points, &c, p.m, p.n, p.k, &mut counters);
            if out.objective > prev * (1.0 + 1e-5) + 1e-4 {
                return false;
            }
            prev = out.objective;
            kernels::update_centroids(&out.sums, &out.counts, &mut c, p.k, p.n);
        }
        true
    });
}

#[test]
fn prop_parallel_assignment_matches_serial_any_shape() {
    // The pool-parallel fused assignment must agree with the serial path on
    // random, deliberately non-block-aligned shapes: labels, counts and
    // per-point mins exactly; f64 accumulations up to merge-order slack.
    let gen = ClusterProblemGen {
        m_range: (1, 3000), // crosses the 2·BLOCK_ROWS parallel threshold
        n_range: (1, 12),
        k_max: 7,
        coord_range: (-50.0, 50.0),
    };
    let pool = ThreadPool::new(3);
    check(9, 40, &gen, |p| {
        let mut rng = Rng::new(31);
        let c = seed_centroids(p, &mut rng);
        let mut c1 = Counters::new();
        let mut c2 = Counters::new();
        let serial = kernels::assign_accumulate(&p.points, &c, p.m, p.n, p.k, &mut c1);
        let par = kernels::assign_accumulate_parallel(
            &pool, &p.points, &c, p.m, p.n, p.k, &mut c2,
        );
        let slack = 1e-6 * serial.objective.abs() + 1e-9;
        serial.labels == par.labels
            && serial.counts == par.counts
            && serial.mins == par.mins
            && (serial.objective - par.objective).abs() <= slack
            && c1.distance_evals == c2.distance_evals
    });
}

#[test]
fn prop_sampler_draws_identical_chunks_across_backends() {
    // The chunk sampler must hand the coordinator byte-identical chunks
    // whether the source is the in-memory dataset, the mmap'd .bmx file, or
    // the buffered .bmx reader — same seed, same indices, same floats.
    let gen = ClusterProblemGen {
        m_range: (2, 300),
        n_range: (1, 8),
        k_max: 4,
        coord_range: (-100.0, 100.0),
    };
    let dir = std::env::temp_dir().join("bigmeans_prop_sampler");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{}.bmx", std::process::id()));
    check(10, 25, &gen, |p| {
        let data = Dataset::from_vec("prop", p.points.clone(), p.m, p.n);
        save_bmx(&data, &path).unwrap();
        let mapped = BmxSource::open(&path).unwrap();
        let buffered = BmxSource::open_buffered(&path).unwrap();
        let s = (p.m / 2).max(1);
        let mut ok = true;
        for (seed, src) in [(1u64, &mapped as &dyn bigmeans::DataSource), (1, &buffered)] {
            let mut mem_sampler = ChunkSampler::new(s, p.n);
            let mut disk_sampler = ChunkSampler::new(s, p.n);
            let mut rng_a = Rng::new(seed ^ 0xC0FFEE);
            let mut rng_b = Rng::new(seed ^ 0xC0FFEE);
            for _ in 0..3 {
                let (mem_chunk, mem_rows) = mem_sampler.sample(&data, &mut rng_a);
                let mem_chunk = mem_chunk.to_vec();
                let (disk_chunk, disk_rows) = disk_sampler.sample(src, &mut rng_b);
                ok &= mem_rows == disk_rows
                    && mem_chunk == disk_chunk
                    && mem_sampler.last_indices() == disk_sampler.last_indices();
            }
        }
        ok
    });
    let _ = std::fs::remove_file(&path);
}

#[test]
fn prop_objective_zero_iff_centroids_cover_points() {
    // Degenerate geometry: if every point IS a centroid, objective is 0.
    let gen = ClusterProblemGen {
        m_range: (1, 8),
        n_range: (1, 4),
        k_max: 8,
        coord_range: (-10.0, 10.0),
    };
    check(7, 60, &gen, |p| {
        if p.k < p.m {
            return true; // only check the covering case
        }
        let mut counters = Counters::new();
        let mut c = p.points.clone();
        c.resize(p.k * p.n, f32::MAX); // pad extra slots far away
        c[..p.m * p.n].copy_from_slice(&p.points);
        let obj = kernels::objective(&p.points, &c, p.m, p.n, p.k, &mut counters);
        obj == 0.0
    });
}
