//! Integration: the out-of-core DataSource engine must be *value
//! transparent* — a seeded Big-means run over a temp `.bmx` file (mmap or
//! buffered) or an indexed CSV reproduces the in-memory run bit-for-bit:
//! same incumbent, same final objective, same assignment. This is the
//! contract that lets the reproduction claim "clusters data it cannot
//! load" without changing a single reported number.

use std::path::PathBuf;

use bigmeans::coordinator::config::{BigMeansConfig, ParallelMode, StopCondition};
use bigmeans::data::bmx::{save_bmx, BmxSource};
use bigmeans::data::convert::csv_to_bmx;
use bigmeans::data::csv_source::CsvSource;
use bigmeans::data::loader;
use bigmeans::data::synth::Synth;
use bigmeans::{BigMeans, BigMeansResult, DataSource, Dataset};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("bigmeans_ooc_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{name}", std::process::id()))
}

fn blobs(m: usize, n: usize, k_true: usize, seed: u64) -> Dataset {
    Synth::GaussianMixture {
        m,
        n,
        k_true,
        spread: 0.3,
        box_half_width: 25.0,
    }
    .generate("ooc", seed)
}

fn sequential_cfg(k: usize, s: usize, chunks: u64) -> BigMeansConfig {
    BigMeansConfig::new(k, s)
        .with_stop(StopCondition::MaxChunks(chunks))
        .with_parallel(ParallelMode::Sequential)
        .with_seed(42)
}

fn assert_bit_identical(a: &BigMeansResult, b: &BigMeansResult, label: &str) {
    assert_eq!(
        a.objective.to_bits(),
        b.objective.to_bits(),
        "{label}: objectives differ: {} vs {}",
        a.objective,
        b.objective
    );
    assert_eq!(
        a.best_chunk_objective.to_bits(),
        b.best_chunk_objective.to_bits(),
        "{label}: incumbent objectives differ"
    );
    assert_eq!(a.centroids, b.centroids, "{label}: centroids differ");
    assert_eq!(a.assignment, b.assignment, "{label}: assignments differ");
    assert_eq!(a.counters, b.counters, "{label}: counters differ");
    assert_eq!(a.improvements, b.improvements, "{label}: improvements differ");
}

#[test]
fn sequential_pipeline_bit_identical_across_backends() {
    let data = blobs(30_000, 6, 5, 1);
    let path = tmp("seq.bmx");
    save_bmx(&data, &path).unwrap();
    let mapped = BmxSource::open(&path).unwrap();
    let buffered = BmxSource::open_buffered(&path).unwrap();

    let run = |src: &dyn DataSource| {
        BigMeans::new(sequential_cfg(5, 2048, 20)).run(src).unwrap()
    };
    let mem = run(&data);
    let via_mmap = run(&mapped);
    let via_pread = run(&buffered);
    assert!(mem.objective.is_finite());
    assert_eq!(mem.assignment.len(), 30_000);
    assert_bit_identical(&mem, &via_mmap, "mem vs mmap");
    assert_bit_identical(&mem, &via_pread, "mem vs buffered");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn chunk_parallel_pipeline_bit_identical_across_backends() {
    // One worker makes the chunk-parallel pipeline deterministic (ticketed
    // chunk budget + a single RNG stream), so the backend comparison can be
    // exact for strategy 2 as well.
    let data = blobs(20_000, 4, 4, 2);
    let path = tmp("par.bmx");
    save_bmx(&data, &path).unwrap();
    let mapped = BmxSource::open(&path).unwrap();

    let run = |src: &dyn DataSource| {
        let mut cfg = BigMeansConfig::new(4, 1024)
            .with_stop(StopCondition::MaxChunks(12))
            .with_parallel(ParallelMode::ChunkParallel)
            .with_seed(7);
        cfg.threads = 1;
        BigMeans::new(cfg).run(src).unwrap()
    };
    let mem = run(&data);
    let ooc = run(&mapped);
    assert_eq!(mem.counters.chunks, 12);
    assert_bit_identical(&mem, &ooc, "chunk-parallel mem vs mmap");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn multithreaded_chunk_parallel_runs_out_of_core() {
    // With several workers the interleaving is racy, so only quality and
    // accounting are asserted — but the data never leaves the mmap.
    let data = blobs(25_000, 4, 4, 3);
    let path = tmp("par_mt.bmx");
    save_bmx(&data, &path).unwrap();
    let mapped = BmxSource::open(&path).unwrap();

    let mut cfg = BigMeansConfig::new(4, 1024)
        .with_stop(StopCondition::MaxChunks(16))
        .with_parallel(ParallelMode::ChunkParallel)
        .with_seed(11);
    cfg.threads = 4;
    let r = BigMeans::new(cfg).run(&mapped).unwrap();
    assert_eq!(r.counters.chunks, 16);
    assert_eq!(r.assignment.len(), 25_000);
    assert!(r.objective.is_finite());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn csv_source_bit_identical_to_materialized_csv() {
    // Round a dataset through CSV text so both sides parse identical
    // decimal strings, then compare indexed-CSV vs in-memory clustering.
    let data = blobs(4_000, 3, 3, 4);
    let path = tmp("src.csv");
    let mut text = String::with_capacity(data.m() * 24);
    for i in 0..data.m() {
        let row = &data.points()[i * 3..(i + 1) * 3];
        text.push_str(&format!("{},{},{}\n", row[0], row[1], row[2]));
    }
    std::fs::write(&path, text).unwrap();

    let materialized = loader::load_csv(&path, None).unwrap();
    let indexed = CsvSource::open(&path).unwrap();
    assert_eq!(indexed.m(), materialized.m());

    let run = |src: &dyn DataSource| {
        BigMeans::new(sequential_cfg(3, 512, 10)).run(src).unwrap()
    };
    let mem = run(&materialized);
    let ooc = run(&indexed);
    assert_bit_identical(&mem, &ooc, "materialized csv vs indexed csv");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn convert_then_cluster_matches_csv_pipeline() {
    // csv → bmx conversion preserves values exactly: clustering the .bmx
    // through mmap equals clustering the materialized CSV.
    let data = blobs(3_000, 2, 3, 5);
    let csv = tmp("conv.csv");
    let bmx = tmp("conv.bmx");
    let mut text = String::new();
    for i in 0..data.m() {
        let row = &data.points()[i * 2..(i + 1) * 2];
        text.push_str(&format!("{},{}\n", row[0], row[1]));
    }
    std::fs::write(&csv, text).unwrap();
    let (m, n) = csv_to_bmx(&csv, &bmx).unwrap();
    assert_eq!((m, n), (3_000, 2));

    let materialized = loader::load_csv(&csv, None).unwrap();
    let mapped = BmxSource::open(&bmx).unwrap();
    let run = |src: &dyn DataSource| {
        BigMeans::new(sequential_cfg(3, 512, 8)).run(src).unwrap()
    };
    assert_bit_identical(
        &run(&materialized),
        &run(&mapped),
        "csv materialized vs converted bmx",
    );
    let _ = std::fs::remove_file(&csv);
    let _ = std::fs::remove_file(&bmx);
}

#[test]
fn inner_parallel_final_pass_identical_across_backends() {
    // The blocked final pass must stay backend-independent when the solver
    // parallelises rows internally (strategy 1).
    let data = blobs(40_000, 5, 4, 6);
    let path = tmp("inner.bmx");
    save_bmx(&data, &path).unwrap();
    let mapped = BmxSource::open(&path).unwrap();

    let run = |src: &dyn DataSource| {
        let mut cfg = BigMeansConfig::new(4, 2048)
            .with_stop(StopCondition::MaxChunks(10))
            .with_parallel(ParallelMode::InnerParallel)
            .with_seed(13);
        cfg.threads = 4;
        BigMeans::new(cfg).run(src).unwrap()
    };
    let mem = run(&data);
    let ooc = run(&mapped);
    assert_bit_identical(&mem, &ooc, "inner-parallel mem vs mmap");
    let _ = std::fs::remove_file(&path);
}
