//! Property tests for the bandit controllers and the reward signal.
//!
//! Pinned properties (the acceptance contract of the tuner subsystem):
//!
//! * rewards are monotone in objective improvement;
//! * both controllers pull every arm at least once before exploiting;
//! * degenerate single-arm portfolios never panic;
//! * controllers concentrate pulls on the better arm once statistics
//!   exist.

use bigmeans::tuner::{
    improvement_reward, BanditController, SoftmaxController, UcbController,
};
use bigmeans::util::rng::Rng;

#[test]
fn reward_is_monotone_in_improvement() {
    // For any fixed `before`, a lower `after` never earns a lower reward.
    for case in 0..200u64 {
        let mut rng = Rng::new(0xF00D + case);
        let before = rng.range_f64(1e-6, 1e9);
        // A descending grid of `after` values from 2×before down to 0.
        let mut afters: Vec<f64> =
            (0..=20).map(|i| before * 2.0 * (1.0 - i as f64 / 20.0)).collect();
        afters.push(0.0);
        let rewards: Vec<f64> = afters.iter().map(|&a| improvement_reward(before, a)).collect();
        for w in rewards.windows(2) {
            assert!(
                w[1] >= w[0],
                "reward must not decrease as the objective improves: {rewards:?}"
            );
        }
        for &r in &rewards {
            assert!((0.0..=1.0).contains(&r), "reward out of range: {r}");
        }
    }
}

#[test]
fn reward_edge_cases() {
    // First finite solution from the all-degenerate start: full reward.
    assert_eq!(improvement_reward(f64::INFINITY, 123.0), 1.0);
    // Worsening, ties, and non-finite results earn nothing.
    assert_eq!(improvement_reward(5.0, 5.0), 0.0);
    assert_eq!(improvement_reward(5.0, 50.0), 0.0);
    assert_eq!(improvement_reward(5.0, f64::INFINITY), 0.0);
    assert_eq!(improvement_reward(5.0, f64::NAN), 0.0);
    assert_eq!(improvement_reward(f64::INFINITY, f64::INFINITY), 0.0);
}

/// Drive a controller for `pulls` rounds with per-arm mean rewards.
fn drive(
    controller: &mut dyn BanditController,
    arm_rewards: &[f64],
    pulls: usize,
    seed: u64,
) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    let mut counts = vec![0u64; arm_rewards.len()];
    for _ in 0..pulls {
        let arm = controller.select(&mut rng);
        assert!(arm < arm_rewards.len(), "selected arm out of range");
        counts[arm] += 1;
        controller.update(arm, arm_rewards[arm]);
    }
    counts
}

#[test]
fn all_arms_pulled_before_exploitation() {
    // Whatever the rewards, the first `n` selections must cover all `n`
    // arms exactly once — forced exploration precedes exploitation.
    for case in 0..50usize {
        let n = 1 + case % 7;
        let rewards: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).fract()).collect();
        let controllers: Vec<Box<dyn BanditController>> = vec![
            Box::new(UcbController::new(n, 1.0)),
            Box::new(SoftmaxController::new(n, 0.1)),
        ];
        for mut c in controllers {
            let mut rng = Rng::new(case as u64);
            let mut seen = vec![false; n];
            for round in 0..n {
                let arm = c.select(&mut rng);
                assert!(
                    !seen[arm],
                    "{}: arm {arm} selected twice in the first {n} rounds (round {round})",
                    c.name()
                );
                seen[arm] = true;
                c.update(arm, rewards[arm]);
            }
            assert!(seen.iter().all(|&s| s), "{}: arms missed in sweep", c.name());
        }
    }
}

#[test]
fn single_arm_portfolio_never_panics() {
    let mut ucb = UcbController::new(1, 2.0);
    let mut soft = SoftmaxController::new(1, 0.01);
    let mut rng = Rng::new(3);
    for i in 0..200 {
        assert_eq!(ucb.select(&mut rng), 0);
        assert_eq!(soft.select(&mut rng), 0);
        // Extreme rewards, including repeated zeros.
        let r = if i % 3 == 0 { 0.0 } else { 1.0 };
        ucb.update(0, r);
        soft.update(0, r);
    }
}

#[test]
fn controllers_exploit_the_better_arm() {
    // Two arms, one clearly better: after a warmup both policies must
    // concentrate a solid majority of pulls on it.
    let counts = drive(&mut UcbController::new(2, 0.5), &[0.1, 0.9], 300, 11);
    assert!(counts[1] > counts[0] * 2, "ucb counts: {counts:?}");
    let counts = drive(&mut SoftmaxController::new(2, 0.05), &[0.85, 0.05], 300, 13);
    assert!(counts[0] > counts[1] * 2, "softmax counts: {counts:?}");
}

#[test]
fn ucb_keeps_exploring_with_large_constant() {
    // A huge exploration constant must keep both arms alive even when one
    // dominates — no starvation.
    let counts = drive(&mut UcbController::new(2, 50.0), &[0.0, 1.0], 400, 17);
    assert!(counts[0] >= 50, "high-c ucb should keep exploring: {counts:?}");
    assert!(counts[1] >= 50, "high-c ucb should keep exploring: {counts:?}");
}

#[test]
fn zero_rewards_degrade_to_round_robin_ish_ucb() {
    // All rewards identical → UCB's bonus term dominates and pulls stay
    // balanced within a factor of two.
    let counts = drive(&mut UcbController::new(4, 1.0), &[0.5; 4], 400, 19);
    let max = *counts.iter().max().unwrap();
    let min = *counts.iter().min().unwrap();
    assert!(max <= min * 2, "balanced rewards should balance pulls: {counts:?}");
}
