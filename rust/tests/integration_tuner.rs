//! Integration: the competitive portfolio tuner.
//!
//! Two contracts are pinned down here:
//!
//! 1. **Determinism** — a single-worker `--mode tune` run with a fixed
//!    seed is bit-reproducible: identical incumbent centroids, identical
//!    final objective bits, identical arm-pull sequence and rewards. This
//!    is what the per-arm RNG stream layout buys.
//! 2. **Competition wins** — within the same shot budget, the tuned run's
//!    final full-dataset objective is no worse than the best fixed
//!    sample-size baseline from the same grid (up to f32 rounding slack:
//!    chunk gathers are permutations, so two runs converging to the same
//!    partition can differ in the last bits of the accumulated means), and
//!    strictly better than the worst fixed baseline.

use bigmeans::coordinator::config::{BigMeansConfig, ParallelMode, StopCondition};
use bigmeans::data::synth::Synth;
use bigmeans::tuner::{run_race, ArmSpec, ControllerKind, TunerConfig};
use bigmeans::{BigMeans, Dataset};

/// Well-separated tight blobs: every full-data local search lands in the
/// global basin, which is what makes the competition assertion sharp.
fn blobs(m: usize, seed: u64) -> Dataset {
    Synth::GaussianMixture {
        m,
        n: 4,
        k_true: 3,
        spread: 0.1,
        box_half_width: 30.0,
    }
    .generate("tuner", seed)
}

fn tuned_cfg(shots: u64, seed: u64) -> BigMeansConfig {
    let mut cfg = BigMeansConfig::new(3, 128)
        .with_stop(StopCondition::MaxChunks(shots))
        .with_parallel(ParallelMode::ChunkParallel)
        .with_seed(seed);
    cfg.threads = 1;
    cfg
}

/// The grid the tests race: two chunk-sized arms and one full-data arm
/// (multiplier large enough to clamp to `m`).
fn grid() -> Vec<ArmSpec> {
    vec![ArmSpec::new(0.5), ArmSpec::new(1.0), ArmSpec::new(1_000_000.0)]
}

#[test]
fn single_worker_tune_is_bit_reproducible() {
    let data = blobs(8_000, 1);
    for controller in [ControllerKind::Ucb, ControllerKind::Softmax] {
        let tuner = TunerConfig::default()
            .with_controller(controller)
            .with_arms(grid());
        let run = || run_race(&tuned_cfg(18, 7), &tuner, &data).unwrap();
        let a = run();
        let b = run();
        assert_eq!(
            a.result.centroids, b.result.centroids,
            "{controller:?}: centroids differ"
        );
        assert_eq!(
            a.result.objective.to_bits(),
            b.result.objective.to_bits(),
            "{controller:?}: objectives differ"
        );
        assert_eq!(
            a.validation_objective.to_bits(),
            b.validation_objective.to_bits(),
            "{controller:?}: validation objectives differ"
        );
        assert_eq!(
            a.trace.pull_sequence, b.trace.pull_sequence,
            "{controller:?}: arm-pull sequences differ"
        );
        assert_eq!(a.trace.rewards, b.trace.rewards, "{controller:?}: rewards differ");
        assert_eq!(a.result.counters, b.result.counters, "{controller:?}: counters differ");
        assert_eq!(a.chosen_chunk_rows, b.chosen_chunk_rows);
    }
}

#[test]
fn tuned_matches_best_fixed_and_beats_worst_fixed() {
    // Same data, same seed, same shot budget for everyone. The grid spans
    // bad (64-row chunks for m=20k) through ideal (full data), so fixed
    // baselines genuinely spread out; the tuner must find the good end.
    let m = 20_000;
    let data = blobs(m, 2);
    let shots = 24u64;

    let mut fixed = Vec::new();
    for spec in grid() {
        let chunk = ((128.0 * spec.multiplier).round() as usize).clamp(3, m);
        let mut cfg = BigMeansConfig::new(3, chunk)
            .with_stop(StopCondition::MaxChunks(shots))
            .with_parallel(ParallelMode::ChunkParallel)
            .with_seed(9);
        cfg.threads = 1;
        let r = BigMeans::new(cfg).run(&data).unwrap();
        assert!(r.objective.is_finite());
        fixed.push(r.objective);
    }
    let best_fixed = fixed.iter().cloned().fold(f64::INFINITY, f64::min);
    let worst_fixed = fixed.iter().cloned().fold(f64::NEG_INFINITY, f64::max);

    let tuner = TunerConfig::default().with_arms(grid());
    let race = run_race(&tuned_cfg(shots, 9), &tuner, &data).unwrap();
    let tuned = race.result.objective;

    // ≤ best fixed, modulo f32 accumulation slack (different gather
    // permutations of the same converged partition differ in the last
    // bits of the means — ~1e-9 relative here, asserted at 1e-6).
    assert!(
        tuned <= best_fixed * (1.0 + 1e-6),
        "tuned {tuned} vs best fixed {best_fixed} (all fixed: {fixed:?})"
    );
    // And the competition must actually matter: strictly better than the
    // worst fixed choice of the same grid.
    assert!(
        tuned < worst_fixed,
        "tuned {tuned} should beat worst fixed {worst_fixed} (all fixed: {fixed:?})"
    );
    assert_eq!(race.trace.total_pulls(), shots);
}

#[test]
fn tune_runs_out_of_core() {
    // The race consumes a DataSource like every other pipeline: clustering
    // through the mmap backend must work and stay deterministic vs RAM.
    use bigmeans::data::bmx::{save_bmx, BmxSource};
    let data = blobs(6_000, 3);
    let path = std::env::temp_dir()
        .join(format!("bigmeans_tuner_{}.bmx", std::process::id()));
    save_bmx(&data, &path).unwrap();
    let mapped = BmxSource::open(&path).unwrap();

    let tuner = TunerConfig::default().with_arms(grid());
    let mem = run_race(&tuned_cfg(10, 5), &tuner, &data).unwrap();
    let ooc = run_race(&tuned_cfg(10, 5), &tuner, &mapped).unwrap();
    assert_eq!(mem.result.centroids, ooc.result.centroids);
    assert_eq!(mem.result.objective.to_bits(), ooc.result.objective.to_bits());
    assert_eq!(mem.trace.pull_sequence, ooc.trace.pull_sequence);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn every_arm_explored_before_budget_exhausts() {
    let data = blobs(4_000, 4);
    let tuner = TunerConfig::default().with_arms(grid());
    let race = run_race(&tuned_cfg(12, 3), &tuner, &data).unwrap();
    assert!(race.trace.arms.iter().all(|a| a.pulls >= 1), "{:?}", race.trace.arms);
    // The first pulls are the forced exploration sweep, in arm-id order.
    assert_eq!(&race.trace.pull_sequence[..3], &[0, 1, 2]);
}
