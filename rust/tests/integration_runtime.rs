//! Integration: the AOT HLO artifacts executed from rust via PJRT must
//! agree with the native kernel substrate — the cross-layer correctness
//! signal (L1/L2 numerics == L3 numerics).
//!
//! Requires `make artifacts` (skipped gracefully when absent so plain
//! `cargo test` works on a fresh checkout).

use bigmeans::coordinator::config::{BigMeansConfig, ParallelMode, StopCondition};
use bigmeans::coordinator::solver::{ChunkSolver, NativeSolver};
use bigmeans::data::synth::Synth;
use bigmeans::kernels;
use bigmeans::metrics::Counters;
use bigmeans::runtime::{default_artifacts_dir, pjrt_bigmeans, Kind, Manifest, PjrtSolver};
use bigmeans::util::rng::Rng;

fn artifacts_ready() -> bool {
    // Without the `pjrt` feature the runtime is a native-fallback stub, so
    // the agreement tests below would trivially compare native to native —
    // skip them (the stub path is covered by pjrt_fallback tests instead).
    cfg!(feature = "pjrt") && default_artifacts_dir().join("manifest.json").exists()
}

fn test_problem(rows: usize, n: usize, k: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let data = Synth::GaussianMixture {
        m: rows,
        n,
        k_true: k,
        spread: 0.4,
        box_half_width: 15.0,
    }
    .generate("t", seed);
    let mut rng = Rng::new(seed);
    let mut c = Counters::new();
    let seed_c = kernels::kmeanspp(data.points(), rows, n, k, 1, &mut rng, &mut c);
    (data.points().to_vec(), seed_c)
}

#[test]
fn manifest_covers_expected_family() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let m = Manifest::load(&default_artifacts_dir()).unwrap();
    for kind in [Kind::Lloyd, Kind::Assign, Kind::KmeansPP] {
        assert!(
            m.select(kind, 1000, 16, 8).is_some(),
            "missing {kind:?} variant for (1000, 16, 8)"
        );
    }
    // Largest default variant: s=16384, n=128, k=32.
    assert!(m.select(Kind::Lloyd, 16384, 128, 32).is_some());
    assert!(m.select(Kind::Lloyd, 16385, 128, 32).is_none());
}

#[test]
fn pjrt_lloyd_matches_native_exact_shape() {
    if !artifacts_ready() {
        return;
    }
    // Shape matches an artifact exactly (1024, 16, 8): no padding involved.
    let (pts, seed_c) = test_problem(1024, 16, 8, 1);
    let solver = PjrtSolver::open(&default_artifacts_dir(), Default::default()).unwrap();
    let native = NativeSolver::sequential(Default::default());
    let mut c1 = Counters::new();
    let mut c2 = Counters::new();
    let a = solver.lloyd(&pts, 1024, 16, 8, &seed_c, &mut c1);
    let b = native.lloyd(&pts, 1024, 16, 8, &seed_c, &mut c2);
    assert_eq!(solver.solve_counts().0, 1, "must run on PJRT, not fallback");
    // Same seed, same algorithm → same local minimum (fp tolerance).
    let rel = (a.objective - b.objective).abs() / b.objective;
    assert!(rel < 1e-3, "objectives diverge: pjrt={} native={}", a.objective, b.objective);
    assert_eq!(a.counts, b.counts, "cluster sizes must match");
    for (x, y) in a.centroids.iter().zip(&b.centroids) {
        assert!((x - y).abs() < 1e-2, "centroid drift {x} vs {y}");
    }
}

#[test]
fn pjrt_lloyd_padded_rows_features_clusters() {
    if !artifacts_ready() {
        return;
    }
    // (700, 10, 5) forces padding in all three dims → (1024, 16, 8).
    let (pts, seed_c) = test_problem(700, 10, 5, 2);
    let solver = PjrtSolver::open(&default_artifacts_dir(), Default::default()).unwrap();
    let native = NativeSolver::sequential(Default::default());
    let mut c1 = Counters::new();
    let mut c2 = Counters::new();
    let a = solver.lloyd(&pts, 700, 10, 5, &seed_c, &mut c1);
    let b = native.lloyd(&pts, 700, 10, 5, &seed_c, &mut c2);
    assert_eq!(solver.solve_counts().0, 1);
    let rel = (a.objective - b.objective).abs() / b.objective;
    assert!(rel < 1e-3, "padded objectives diverge: {} vs {}", a.objective, b.objective);
    assert_eq!(a.counts.len(), 5);
    assert_eq!(a.counts.iter().sum::<u64>(), 700);
}

#[test]
fn pjrt_assign_matches_native_blocked() {
    if !artifacts_ready() {
        return;
    }
    // rows > largest variant (16384) exercises the blocking path.
    let (pts, seed_c) = test_problem(20_000, 8, 6, 3);
    let solver = PjrtSolver::open(&default_artifacts_dir(), Default::default()).unwrap();
    let native = NativeSolver::sequential(Default::default());
    let mut c1 = Counters::new();
    let mut c2 = Counters::new();
    let (la, ma) = solver.assign(&pts, 20_000, 8, 6, &seed_c, &mut c1);
    let (lb, mb) = native.assign(&pts, 20_000, 8, 6, &seed_c, &mut c2);
    assert_eq!(la, lb, "labels must match exactly");
    let mut worst = 0f32;
    for (x, y) in ma.iter().zip(&mb) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst < 1e-2, "min-distance drift {worst}");
    assert_eq!(c1.distance_evals, c2.distance_evals);
}

#[test]
fn pjrt_kmeanspp_selects_data_points() {
    if !artifacts_ready() {
        return;
    }
    let (pts, _) = test_problem(1024, 16, 8, 4);
    let solver = PjrtSolver::open(&default_artifacts_dir(), Default::default()).unwrap();
    let mut rng = Rng::new(9);
    let mut c = Counters::new();
    let cs = solver.kmeanspp(&pts, 1024, 16, 8, &mut rng, &mut c);
    assert_eq!(cs.len(), 8 * 16);
    for j in 0..8 {
        let cj = &cs[j * 16..(j + 1) * 16];
        let mut best = f32::INFINITY;
        for i in 0..1024 {
            let d: f32 = pts[i * 16..(i + 1) * 16]
                .iter()
                .zip(cj)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            best = best.min(d);
        }
        assert!(best < 1e-6, "centroid {j} not a data point (d²={best})");
    }
}

#[test]
fn pjrt_fallback_on_oversized_shape() {
    if !artifacts_ready() {
        return;
    }
    // n=200 exceeds every artifact (max 128) → native fallback must kick in.
    let (pts, seed_c) = test_problem(256, 200, 4, 5);
    let solver = PjrtSolver::open(&default_artifacts_dir(), Default::default()).unwrap();
    let mut c = Counters::new();
    let r = solver.lloyd(&pts, 256, 200, 4, &seed_c, &mut c);
    assert!(r.objective.is_finite());
    assert_eq!(solver.solve_counts(), (0, 1), "must have fallen back to native");
}

#[test]
fn bigmeans_end_to_end_on_pjrt_engine() {
    if !artifacts_ready() {
        return;
    }
    let data = Synth::GaussianMixture {
        m: 8000,
        n: 12,
        k_true: 6,
        spread: 0.3,
        box_half_width: 20.0,
    }
    .generate("e2e", 7);
    let cfg = BigMeansConfig::new(6, 1024)
        .with_stop(StopCondition::MaxChunks(15))
        .with_parallel(ParallelMode::Sequential)
        .with_seed(11);
    let pjrt = pjrt_bigmeans(cfg.clone(), &default_artifacts_dir())
        .unwrap()
        .run(&data)
        .unwrap();
    let native = bigmeans::BigMeans::new(cfg).run(&data).unwrap();
    assert!(pjrt.objective.is_finite());
    // Same seeds → same chunk draws; engines differ only in fp details, so
    // the final objectives should be very close.
    let rel = (pjrt.objective - native.objective).abs() / native.objective;
    assert!(
        rel < 0.05,
        "pjrt {} vs native {} (rel {rel})",
        pjrt.objective,
        native.objective
    );
    assert_eq!(pjrt.assignment.len(), 8000);
}
