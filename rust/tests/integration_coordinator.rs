//! Integration tests for the L3 coordinator across engines, modes and
//! datasets: quality vs references, failure injection, and the paper's
//! qualitative claims at module boundaries.

use std::time::Duration;

use bigmeans::baselines::{ForgyKMeans, KMeansPP, MsscAlgorithm};
use bigmeans::coordinator::config::{BigMeansConfig, ParallelMode, StopCondition};
use bigmeans::data::{catalog, Synth};
use bigmeans::kernels;
use bigmeans::metrics::Counters;
use bigmeans::BigMeans;

fn mixture(m: usize, n: usize, k_true: usize, seed: u64) -> bigmeans::Dataset {
    Synth::GaussianMixture {
        m,
        n,
        k_true,
        spread: 0.3,
        box_half_width: 25.0,
    }
    .generate("mix", seed)
}

#[test]
fn bigmeans_matches_full_kmeanspp_quality_on_blobs() {
    // On separable data with a fair budget, Big-means should land within a
    // few percent of full-data K-means++ (the paper's accuracy claim).
    let data = mixture(30_000, 6, 8, 1);
    let cfg = BigMeansConfig::new(8, 2048)
        .with_stop(StopCondition::MaxChunks(60))
        .with_parallel(ParallelMode::Sequential)
        .with_seed(3);
    let bm = BigMeans::new(cfg).run(&data).unwrap();
    let pp = KMeansPP { threads: 1, ..Default::default() }
        .run(&data, 8, 3)
        .unwrap();
    let ratio = bm.objective / pp.objective;
    assert!(
        ratio < 1.10,
        "big-means {:.4e} vs kmeans++ {:.4e} (ratio {ratio:.3})",
        bm.objective,
        pp.objective
    );
}

#[test]
fn bigmeans_uses_fraction_of_distance_evals_vs_forgy() {
    // The headline scalability claim: far fewer distance evaluations than
    // full-dataset iterating algorithms on big data.
    let data = mixture(120_000, 8, 10, 2);
    let mut cfg = BigMeansConfig::new(10, 1024)
        .with_stop(StopCondition::MaxChunks(25))
        .with_parallel(ParallelMode::Sequential)
        .with_seed(5);
    // Search phase only — the paper notes the final assignment pass is
    // optional (§4.1) and it's the only full-m work Big-means ever does.
    cfg.skip_final_assignment = true;
    let bm = BigMeans::new(cfg.clone()).run(&data).unwrap();
    let forgy = ForgyKMeans { threads: 1, ..Default::default() }
        .run(&data, 10, 5)
        .unwrap();
    assert!(
        bm.counters.distance_evals * 2 < forgy.counters.distance_evals,
        "bigmeans n_d {} should be ≪ forgy n_d {}",
        bm.counters.distance_evals,
        forgy.counters.distance_evals
    );
    // …at comparable quality (within 15% on blobs), judged on the full SSE.
    cfg.skip_final_assignment = false;
    let bm_full = BigMeans::new(cfg).run(&data).unwrap();
    assert!(bm_full.objective < forgy.objective * 1.15);
}

#[test]
fn incumbent_chunk_objective_is_monotone_over_budget() {
    // Keep-the-best ⇒ larger chunk budgets never worsen the incumbent.
    let data = mixture(20_000, 5, 6, 3);
    let mut prev = f64::INFINITY;
    for &chunks in &[1u64, 4, 16, 64] {
        let mut cfg = BigMeansConfig::new(6, 1024)
            .with_stop(StopCondition::MaxChunks(chunks))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(9);
        cfg.skip_final_assignment = true;
        let r = BigMeans::new(cfg).run(&data).unwrap();
        assert!(
            r.best_chunk_objective <= prev * 1.000001,
            "chunk budget {chunks}: {} > prev {prev}",
            r.best_chunk_objective
        );
        prev = r.best_chunk_objective;
    }
}

#[test]
fn degenerate_centroids_reseeded_not_leaked() {
    // k far above k_true forces degeneracy every chunk; the final
    // assignment must still produce a finite objective and valid labels.
    let data = mixture(5_000, 4, 2, 4);
    let cfg = BigMeansConfig::new(16, 512)
        .with_stop(StopCondition::MaxChunks(12))
        .with_parallel(ParallelMode::Sequential)
        .with_seed(1);
    let r = BigMeans::new(cfg).run(&data).unwrap();
    assert!(r.objective.is_finite());
    assert!(r.assignment.iter().all(|&a| (a as usize) < 16));
    let forgy = ForgyKMeans { threads: 1, ..Default::default() }
        .run(&data, 16, 1)
        .unwrap();
    assert!(r.objective < forgy.objective * 1.5);
}

#[test]
fn all_parallel_modes_agree_in_quality() {
    let data = mixture(30_000, 6, 6, 5);
    let mk = |mode| {
        BigMeansConfig::new(6, 2048)
            .with_stop(StopCondition::MaxTime(Duration::from_millis(400)))
            .with_parallel(mode)
            .with_seed(11)
    };
    let seq = BigMeans::new(mk(ParallelMode::Sequential)).run(&data).unwrap();
    let inner = BigMeans::new(mk(ParallelMode::InnerParallel)).run(&data).unwrap();
    let chunks = BigMeans::new(mk(ParallelMode::ChunkParallel)).run(&data).unwrap();
    for (label, r) in [("seq", &seq), ("inner", &inner), ("chunks", &chunks)] {
        assert!(
            r.objective <= seq.objective * 1.25,
            "{label} objective {:.4e} off vs seq {:.4e}",
            r.objective,
            seq.objective
        );
    }
}

#[test]
fn order_independence_of_dataset_rows() {
    // Requirement 8 (§2.2): results must not depend on row order. Uniform
    // sampling guarantees distributional equality; with a fixed seed the
    // chunks differ, so we compare *quality*, not bit-equality.
    let data = mixture(10_000, 4, 5, 6);
    let n = data.n();
    let mut rev = Vec::with_capacity(data.points().len());
    for i in (0..data.m()).rev() {
        rev.extend_from_slice(&data.points()[i * n..(i + 1) * n]);
    }
    let data_rev = bigmeans::Dataset::from_vec("rev", rev, data.m(), n);
    let mk = || {
        BigMeansConfig::new(5, 1024)
            .with_stop(StopCondition::MaxChunks(30))
            .with_parallel(ParallelMode::Sequential)
            .with_seed(13)
    };
    let a = BigMeans::new(mk()).run(&data).unwrap();
    let b = BigMeans::new(mk()).run(&data_rev).unwrap();
    let rel = (a.objective - b.objective).abs() / a.objective;
    assert!(rel < 0.10, "order dependence: {} vs {}", a.objective, b.objective);
}

#[test]
fn full_objective_consistent_with_manual_evaluation() {
    let data = mixture(8_000, 5, 4, 7);
    let cfg = BigMeansConfig::new(4, 1024)
        .with_stop(StopCondition::MaxChunks(10))
        .with_parallel(ParallelMode::Sequential)
        .with_seed(17);
    let r = BigMeans::new(cfg).run(&data).unwrap();
    let mut c = Counters::new();
    let manual =
        kernels::objective(data.points(), &r.centroids, data.m(), data.n(), 4, &mut c);
    let rel = (manual - r.objective).abs() / manual;
    assert!(rel < 1e-6, "reported {} vs manual {}", r.objective, manual);
}

#[test]
fn catalog_entry_runs_end_to_end() {
    let entry = catalog::find("D15112").unwrap();
    let data = entry.generate(1);
    let cfg = BigMeansConfig::new(5, entry.chunk_size)
        .with_stop(StopCondition::TimeOrChunks(
            Duration::from_secs_f64(entry.cpu_max_secs),
            50,
        ))
        .with_seed(23);
    let r = BigMeans::new(cfg).run(&data).unwrap();
    assert!(r.objective.is_finite());
    assert_eq!(r.assignment.len(), entry.m);
}
