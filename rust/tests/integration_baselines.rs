//! Integration tests across the §5 baseline roster: cross-algorithm
//! quality ordering on reference data, failure modes, and the bench
//! harness end-to-end.

use bigmeans::baselines::{
    AlgoFailure, DaMssc, ForgyKMeans, KMeansPP, KMeansParallel, LightweightCoreset,
    LmbmClust, MsscAlgorithm, Wards,
};
use bigmeans::bench_harness::{self, tables};
use bigmeans::data::{catalog, Synth};

fn blobs(m: usize, k_true: usize, seed: u64) -> bigmeans::Dataset {
    Synth::GaussianMixture {
        m,
        n: 4,
        k_true,
        spread: 0.25,
        box_half_width: 20.0,
    }
    .generate("blobs", seed)
}

#[test]
fn every_baseline_solves_small_blobs() {
    let data = blobs(2_000, 4, 1);
    let algos: Vec<Box<dyn MsscAlgorithm>> = vec![
        Box::new(ForgyKMeans { threads: 1, ..Default::default() }),
        Box::new(KMeansPP { threads: 1, ..Default::default() }),
        Box::new(KMeansParallel { threads: 1, ..Default::default() }),
        Box::new(Wards::default()),
        Box::new(LmbmClust::default()),
        Box::new(DaMssc::new(256, 6)),
        Box::new(LightweightCoreset::new(256)),
    ];
    let mut objectives = Vec::new();
    for algo in &algos {
        let r = algo
            .run(&data, 4, 7)
            .unwrap_or_else(|e| panic!("{} failed: {e}", algo.name()));
        assert!(r.objective.is_finite(), "{}", algo.name());
        assert_eq!(r.centroids.len(), 16, "{}", algo.name());
        objectives.push((algo.name(), r.objective));
    }
    // On separable blobs every algorithm should land within 3× of the best.
    let best = objectives.iter().map(|(_, o)| *o).fold(f64::INFINITY, f64::min);
    for (name, obj) in &objectives {
        assert!(
            *obj <= best * 3.0,
            "{name} objective {obj:.4e} is an outlier (best {best:.4e})"
        );
    }
}

#[test]
fn accurate_methods_beat_forgy_on_hard_data() {
    // The paper's quality ordering: Ward's / LMBM / K-means++ are the
    // accurate end, Forgy the noisy end. Use a many-cluster problem where
    // uniform seeding collapses clusters.
    let data = Synth::RandomClusters {
        m: 3_000,
        n: 3,
        k_true: 10,
        max_spread: 1.0,
    }
    .generate("hard", 3);
    let k = 10;
    let mut forgy_mean = 0.0;
    let mut pp_mean = 0.0;
    let runs = 5;
    for seed in 0..runs {
        forgy_mean += ForgyKMeans { threads: 1, ..Default::default() }
            .run(&data, k, seed)
            .unwrap()
            .objective;
        pp_mean += KMeansPP { threads: 1, ..Default::default() }
            .run(&data, k, seed)
            .unwrap()
            .objective;
    }
    forgy_mean /= runs as f64;
    pp_mean /= runs as f64;
    let ward = Wards::default().run(&data, k, 0).unwrap().objective;
    assert!(
        pp_mean <= forgy_mean * 1.02,
        "kmeans++ mean {pp_mean:.4e} vs forgy {forgy_mean:.4e}"
    );
    assert!(
        ward <= forgy_mean * 1.10,
        "ward {ward:.4e} vs forgy mean {forgy_mean:.4e}"
    );
}

#[test]
fn wards_oom_matches_paper_dash_semantics() {
    // Default Ward's cap is 512 MiB for the m² matrix → the large catalog
    // sets must fail exactly like the paper's "—" entries.
    let entry = catalog::find("HEPMASS").unwrap();
    let data = entry.generate(1);
    match Wards::default().run(&data, 5, 0) {
        Err(AlgoFailure::OutOfMemory { .. }) => {}
        other => panic!("expected Ward's OOM on m={}, got {other:?}", data.m()),
    }
}

#[test]
fn paper_cost_ordering_on_large_data() {
    // On a "large" set: Big-means and Forgy are the cheap end; K-means||
    // pays the multi-pass init tax; LMBM is the expensive end.
    let data = blobs(40_000, 6, 5);
    let k = 6;
    let forgy = ForgyKMeans { threads: 1, ..Default::default() }
        .run(&data, k, 1)
        .unwrap();
    let par = KMeansParallel { threads: 1, ..Default::default() }
        .run(&data, k, 1)
        .unwrap();
    let lmbm = LmbmClust { time_budget_secs: 120.0, ..Default::default() }
        .run(&data, k, 1)
        .unwrap();
    assert!(
        par.counters.distance_evals > forgy.counters.distance_evals,
        "k-means|| init should cost more evals than forgy ({} vs {})",
        par.counters.distance_evals,
        forgy.counters.distance_evals
    );
    assert!(
        lmbm.cpu_total_secs() > forgy.cpu_total_secs(),
        "lmbm {}s should out-cost forgy {}s",
        lmbm.cpu_total_secs(),
        forgy.cpu_total_secs()
    );
}

#[test]
fn harness_generates_complete_paper_tables() {
    // End-to-end through the bench harness on the smallest catalog entry
    // with the full roster — every table artifact must materialise.
    let entry = catalog::find("D15112").unwrap();
    let data = entry.generate(9);
    let roster = bench_harness::paper_roster(&entry);
    let exp = bench_harness::run_experiment(&data, &roster, &[2, 5], 2, 11);
    let summary = tables::summary_table(&exp);
    assert_eq!(summary.rows.len(), roster.len() * 2);
    // Big-Means must have succeeded everywhere.
    for row in summary.rows.iter().filter(|r| r.algorithm == "Big-Means") {
        assert!(row.ea.is_some(), "Big-Means failed at k={}", row.k);
    }
    let details = tables::details_table(&exp);
    assert!(!details.is_empty());
    let scores = tables::dataset_scores(&exp);
    assert_eq!(scores.len(), roster.len());
    let t4 = tables::table4(&[scores]);
    let bm = t4.iter().find(|r| r.algorithm == "Big-Means").unwrap();
    assert!(bm.mean_pct >= 0.0 && bm.mean_pct <= 100.0);
}

#[test]
fn coreset_cheaper_than_full_but_close() {
    let data = blobs(20_000, 5, 7);
    let coreset = LightweightCoreset::new(1024).run(&data, 5, 3).unwrap();
    let pp = KMeansPP { threads: 1, ..Default::default() }
        .run(&data, 5, 3)
        .unwrap();
    assert!(coreset.objective <= pp.objective * 1.25);
    assert!(coreset.counters.distance_evals < pp.counters.distance_evals);
}
