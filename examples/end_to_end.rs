//! End-to-end driver: proves the full three-layer stack composes.
//!
//! * generates a realistic big-data workload (a catalog dataset mirroring
//!   HEPMASS at laptop scale: 160k × 27);
//! * runs **Big-means on the PJRT engine** — the Pallas-kernel-backed,
//!   JAX-lowered, AOT-compiled HLO executables driven from the rust
//!   coordinator (Layer 1 → Layer 2 → Layer 3);
//! * cross-checks the native engine on the same seeds;
//! * runs the strongest cheap baseline (K-means++) for the paper's
//!   headline comparison: equal-or-better SSE at a fraction of the time;
//! * prints the rows EXPERIMENTS.md records.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::time::Duration;

use bigmeans::baselines::{KMeansPP, MsscAlgorithm};
use bigmeans::coordinator::config::{BigMeansConfig, ParallelMode, StopCondition};
use bigmeans::data::catalog;
use bigmeans::metrics::relative_error;
use bigmeans::runtime::{default_artifacts_dir, pjrt_bigmeans};
use bigmeans::BigMeans;

fn main() {
    let entry = catalog::find("HEPMASS").expect("catalog");
    let data = entry.generate(20220418);
    let k = 15;
    println!("=== Big-means end-to-end driver ===");
    println!(
        "workload: {} (m={}, n={}), k={k}, chunk s={}, budget {:.1}s\n",
        entry.name,
        data.m(),
        data.n(),
        entry.chunk_size,
        entry.cpu_max_secs
    );

    let cfg = BigMeansConfig::new(k, entry.chunk_size)
        .with_stop(StopCondition::MaxTime(Duration::from_secs_f64(
            entry.cpu_max_secs,
        )))
        .with_parallel(ParallelMode::Sequential)
        .with_seed(4242);

    // --- Layer 1+2+3: PJRT engine over the AOT artifacts ---
    let artifacts = default_artifacts_dir();
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let t0 = std::time::Instant::now();
    let pjrt = pjrt_bigmeans(cfg.clone(), &artifacts)
        .expect("open PJRT runtime")
        .run(&data)
        .expect("pjrt run");
    let pjrt_wall = t0.elapsed().as_secs_f64();

    // --- Native engine, same seeds (cross-check) ---
    let t1 = std::time::Instant::now();
    let native = BigMeans::new(cfg).run(&data).expect("native run");
    let native_wall = t1.elapsed().as_secs_f64();

    // --- Baseline: K-means++ on the full dataset ---
    let t2 = std::time::Instant::now();
    let pp = KMeansPP::default().run(&data, k, 4242).expect("kmeans++");
    let pp_wall = t2.elapsed().as_secs_f64();

    let f_best = pjrt.objective.min(native.objective).min(pp.objective);
    println!("{:<28} {:>14} {:>9} {:>9} {:>12}", "engine/algorithm", "SSE", "E_A %", "wall s", "n_d");
    let mut row = |name: &str, sse: f64, wall: f64, nd: u64| {
        println!(
            "{:<28} {:>14.6e} {:>9.3} {:>9.3} {:>12.3e}",
            name,
            sse,
            relative_error(sse, f_best),
            wall,
            nd as f64
        );
    };
    row("Big-means (PJRT/AOT-HLO)", pjrt.objective, pjrt_wall, pjrt.counters.distance_evals);
    row("Big-means (native)", native.objective, native_wall, native.counters.distance_evals);
    row("K-means++ (full data)", pp.objective, pp_wall, pp.counters.distance_evals);

    println!(
        "\nchunks: pjrt={}, native={}  |  improvements: pjrt={}, native={}",
        pjrt.counters.chunks, native.counters.chunks, pjrt.improvements, native.improvements
    );

    // Headline checks (the paper's claim, scaled): Big-means reaches
    // within a few % of the best SSE using far fewer distance evals.
    let ea_pjrt = relative_error(pjrt.objective, f_best);
    let evals_ratio =
        pp.counters.distance_evals as f64 / pjrt.counters.distance_evals.max(1) as f64;
    println!("\nheadline: Big-means E_A = {ea_pjrt:.2}%  |  K-means++ used {evals_ratio:.1}× the distance evals");
    assert!(pjrt.objective.is_finite() && pjrt.assignment.len() == data.m());
    assert!(
        ea_pjrt < 30.0,
        "Big-means should land near the best solution (E_A {ea_pjrt:.2}%)"
    );
    println!("\nOK — all three layers composed (Pallas kernel → JAX HLO → PJRT → rust coordinator).");
}
