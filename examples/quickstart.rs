//! Quickstart: cluster a synthetic big dataset with Big-means in ~20 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::time::Duration;

use bigmeans::coordinator::config::StopCondition;
use bigmeans::data::Synth;
use bigmeans::{BigMeans, BigMeansConfig};

fn main() {
    // 100k points, 8 features, 10 latent clusters.
    let data = Synth::GaussianMixture {
        m: 100_000,
        n: 8,
        k_true: 10,
        spread: 0.5,
        box_half_width: 25.0,
    }
    .generate("quickstart", 42);

    // Big-means: k=10 clusters, chunks of 4096 points, 2-second budget.
    let config = BigMeansConfig::new(10, 4096)
        .with_stop(StopCondition::MaxTime(Duration::from_secs(2)))
        .with_seed(7);

    let result = BigMeans::new(config).run(&data).expect("clustering failed");

    println!("Big-means on {} points:", data.m());
    println!("  full-dataset SSE     : {:.4e}", result.objective);
    println!("  chunks processed     : {}", result.counters.chunks);
    println!("  incumbent updates    : {}", result.improvements);
    println!(
        "  distance evaluations : {:.2e}  (vs {:.2e} for ONE full K-means pass)",
        result.counters.distance_evals as f64,
        (data.m() * 10) as f64
    );
    println!(
        "  search/final time    : {:.3}s / {:.3}s",
        result.cpu_init_secs, result.cpu_full_secs
    );

    // The final centroids and per-point assignment are ready to use:
    assert_eq!(result.centroids.len(), 10 * data.n());
    assert_eq!(result.assignment.len(), data.m());
    let sizes = {
        let mut s = vec![0usize; 10];
        for &a in &result.assignment {
            s[a as usize] += 1;
        }
        s
    };
    println!("  cluster sizes        : {sizes:?}");
}
