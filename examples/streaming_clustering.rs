//! Streaming clustering: Big-means over an unbounded data stream
//! (paper §4.1 — "accurate clustering results within a predefined time
//! frame even for an infinitely large dataset").
//!
//! A producer thread emits chunks of a slowly *drifting* mixture through a
//! bounded, backpressured queue; the Big-means consumer keeps improving its
//! incumbent without ever holding more than a few chunks in memory.
//!
//! ```bash
//! cargo run --release --example streaming_clustering
//! ```

use std::sync::Arc;
use std::time::Duration;

use bigmeans::coordinator::config::{BigMeansConfig, ParallelMode, StopCondition};
use bigmeans::coordinator::stream::{ChunkQueue, StreamChunk, StreamingBigMeans};
use bigmeans::util::rng::Rng;

const N: usize = 6; // feature dim
const K: usize = 4; // clusters
const CHUNK_ROWS: usize = 2048;

/// Emit one chunk of the (drifting) ground-truth mixture.
fn emit_chunk(rng: &mut Rng, drift: f64) -> StreamChunk {
    // Four centers on a square, drifting along the first axis.
    let centers: [[f64; 2]; 4] = [[0.0, 0.0], [40.0, 0.0], [0.0, 40.0], [40.0, 40.0]];
    let mut points = Vec::with_capacity(CHUNK_ROWS * N);
    for _ in 0..CHUNK_ROWS {
        let c = centers[rng.usize(4)];
        points.push((c[0] + drift + 0.8 * rng.gaussian()) as f32);
        points.push((c[1] + 0.8 * rng.gaussian()) as f32);
        for _ in 2..N {
            points.push(0.5 * rng.gaussian() as f32);
        }
    }
    StreamChunk { points, rows: CHUNK_ROWS }
}

fn main() {
    let queue = ChunkQueue::new(8); // bounded: producer feels backpressure

    // Producer: 120 chunks (~250k points), drifting by +2.0 over the run.
    let producer = {
        let q = Arc::clone(&queue);
        std::thread::spawn(move || {
            let mut rng = Rng::new(1);
            for i in 0..120 {
                let drift = i as f64 / 60.0;
                if !q.push(emit_chunk(&mut rng, drift)) {
                    break; // consumer closed early
                }
            }
            q.close();
        })
    };

    let config = BigMeansConfig::new(K, CHUNK_ROWS)
        .with_stop(StopCondition::MaxTime(Duration::from_secs(10)))
        .with_parallel(ParallelMode::Sequential)
        .with_seed(99);
    let engine = StreamingBigMeans::new(config, N);

    let t0 = std::time::Instant::now();
    let result = engine.run(&queue);
    producer.join().unwrap();

    println!("streamed clustering finished in {:.2}s", t0.elapsed().as_secs_f64());
    println!("  chunks consumed      : {}", result.chunks_processed);
    println!("  incumbent updates    : {}", result.improvements);
    println!("  best chunk objective : {:.4e}", result.best_chunk_objective);
    println!("  centroids (first 2 dims):");
    for j in 0..K {
        let c = &result.centroids[j * N..j * N + 2];
        println!("    c{j} = ({:8.3}, {:8.3})", c[0], c[1]);
    }
    // The four centroids should straddle the drifted square corners.
    let mut found = 0;
    for corner in [[0.0, 0.0], [40.0, 0.0], [0.0, 40.0], [40.0, 40.0]] {
        if (0..K).any(|j| {
            let c = &result.centroids[j * N..j * N + 2];
            (c[0] as f64 - corner[0]).abs() < 4.0 && (c[1] as f64 - corner[1]).abs() < 4.0
        }) {
            found += 1;
        }
    }
    println!("  corners recovered    : {found}/4");
}
