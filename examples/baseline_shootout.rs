//! Baseline shootout: Big-means vs the paper's §5 roster on one catalog
//! dataset, printing a mini version of the paper's summary tables.
//!
//! ```bash
//! cargo run --release --example baseline_shootout [dataset-name] [k]
//! ```

use bigmeans::baselines::MsscAlgorithm;
use bigmeans::bench_harness::{paper_roster, run_experiment};
use bigmeans::bench_harness::tables::summary_table;
use bigmeans::data::catalog;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().map(|s| s.as_str()).unwrap_or("Skin Segmentation");
    let k: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(10);

    let entry = catalog::find(name).unwrap_or_else(|| {
        eprintln!("unknown dataset '{name}', falling back to Skin Segmentation");
        catalog::find("Skin Segmentation").unwrap()
    });
    let data = entry.generate(20220418);
    println!(
        "dataset: {} (m={}, n={}, chunk s={})  k={k}",
        entry.name,
        data.m(),
        data.n(),
        entry.chunk_size
    );
    println!("paper shape ref: m={}, n={}\n", entry.paper_m, entry.paper_n);

    let roster = paper_roster(&entry);
    let names: Vec<&str> = roster.iter().map(|a| a.name()).collect();
    println!("roster: {names:?}\n");

    let exp = run_experiment(&data, &roster, &[k], 3, 7);
    let table = summary_table(&exp);

    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "algorithm", "E_A min%", "E_A mean%", "E_A max%", "cpu mean", "status"
    );
    for row in &table.rows {
        match (row.ea, row.cpu) {
            (Some(ea), Some(cpu)) => println!(
                "{:<22} {:>10.3} {:>10.3} {:>10.3} {:>9.3}s {:>10}",
                row.algorithm, ea.min, ea.mean, ea.max, cpu.mean, "ok"
            ),
            _ => println!(
                "{:<22} {:>10} {:>10} {:>10} {:>10} {:>10}",
                row.algorithm, "—", "—", "—", "—", "failed"
            ),
        }
    }
    if let Some(row) = table.rows.first() {
        println!("\nf_best* = {:.6e} (best across all runs here)", row.f_best);
    }
}
