//! Out-of-core Big-means: cluster a dataset through the mmap'd `.bmx`
//! backend and verify the result is bit-for-bit identical to clustering
//! the same bytes fully loaded in RAM.
//!
//! The demo (1) streams a 2,000,000 × 8 Gaussian-mixture dataset to disk
//! with O(block) memory — the writer never holds the matrix, (2) clusters
//! it through `BmxSource` (mmap: only the sampled pages are ever touched),
//! and (3) reruns the identical seeded configuration on an in-memory copy,
//! asserting the final SSE matches bit-for-bit. Nothing in Big-means
//! depends on where the bytes live — exactly the paper's decomposition
//! argument, made executable.
//!
//! ```bash
//! cargo run --release --example out_of_core
//! ```

use std::time::Instant;

use bigmeans::coordinator::config::{ParallelMode, StopCondition};
use bigmeans::data::bmx::{BmxSource, BmxWriter};
use bigmeans::data::loader;
use bigmeans::util::rng::Rng;
use bigmeans::{BigMeans, BigMeansConfig, DataSource};

const M: usize = 2_000_000;
const N: usize = 8;
const K_TRUE: usize = 10;
const WRITE_BLOCK_ROWS: usize = 65_536;

fn main() {
    let path = std::env::temp_dir().join("bigmeans_out_of_core_demo.bmx");

    // --- 1. Stream the dataset to disk without materializing it. -------
    let t0 = Instant::now();
    let mut rng = Rng::new(20220418);
    let centers: Vec<Vec<f64>> = (0..K_TRUE)
        .map(|_| (0..N).map(|_| rng.range_f64(-25.0, 25.0)).collect())
        .collect();
    let mut writer = BmxWriter::create(&path, N).expect("create .bmx");
    let mut block = vec![0f32; WRITE_BLOCK_ROWS * N];
    let mut written = 0usize;
    while written < M {
        let rows = WRITE_BLOCK_ROWS.min(M - written);
        for r in 0..rows {
            let c = &centers[rng.usize(K_TRUE)];
            for d in 0..N {
                block[r * N + d] = (c[d] + 0.5 * rng.gaussian()) as f32;
            }
        }
        writer.write_rows(&block[..rows * N]).expect("write rows");
        written += rows;
    }
    let rows = writer.finish().expect("finish .bmx");
    let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    println!(
        "wrote {rows} × {N} rows ({:.1} MiB) in {:.2}s → {}",
        bytes as f64 / (1 << 20) as f64,
        t0.elapsed().as_secs_f64(),
        path.display()
    );

    // --- 2. Cluster out-of-core through the mmap backend. --------------
    // Chunk-count stop (not wall-clock): both runs must do identical work
    // for the bit-for-bit comparison below to be meaningful.
    let config = BigMeansConfig::new(/*k=*/ 8, /*chunk_size=*/ 4096)
        .with_stop(StopCondition::MaxChunks(40))
        .with_parallel(ParallelMode::Sequential)
        .with_seed(7);

    let source = BmxSource::open(&path).expect("open .bmx");
    assert_eq!((source.m(), source.n()), (M, N));
    println!(
        "backend: {} (chunks gathered on demand, resident set ≈ sampled pages)",
        if source.is_mmap() { "mmap" } else { "buffered pread" }
    );
    let t1 = Instant::now();
    let ooc = BigMeans::new(config.clone()).run(&source).expect("out-of-core run");
    println!(
        "out-of-core: SSE {:.6e} | {} chunks | {:.2e} distance evals | {:.2}s",
        ooc.objective,
        ooc.counters.chunks,
        ooc.counters.distance_evals as f64,
        t1.elapsed().as_secs_f64()
    );

    // --- 3. Same seed, same bytes, fully in RAM: must match exactly. ---
    let resident = loader::load(&path).expect("materialize .bmx");
    let t2 = Instant::now();
    let mem = BigMeans::new(config).run(&resident).expect("in-memory run");
    println!(
        "in-memory:   SSE {:.6e} | {} chunks | {:.2e} distance evals | {:.2}s",
        mem.objective,
        mem.counters.chunks,
        mem.counters.distance_evals as f64,
        t2.elapsed().as_secs_f64()
    );

    assert_eq!(
        ooc.objective.to_bits(),
        mem.objective.to_bits(),
        "backends must agree bit-for-bit"
    );
    assert_eq!(ooc.centroids, mem.centroids);
    assert_eq!(ooc.assignment, mem.assignment);
    println!("✓ identical objective bit-for-bit across backends");

    let _ = std::fs::remove_file(&path);
}
