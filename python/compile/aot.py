"""AOT pipeline: lower the L2 computations to HLO text artifacts.

Run once at build time (`make artifacts`); the rust runtime loads the
emitted `artifacts/*.hlo.txt` via `HloModuleProto::from_text_file` and
executes them on the PJRT CPU client. Python never runs after this.

Interchange format is HLO *text*, NOT `.serialize()`: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Because PJRT executables are fixed-shape, we emit a family of
`(s, n, k)` variants and a `manifest.json` describing them; the rust
runtime picks the smallest fitting variant and pads (see the padding
contract in model.py's docstring).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import assign as assign_kernel

# Default variant family. Chunk sizes are multiples of the kernel block;
# feature dims are zero-pad targets (distance-preserving); cluster counts
# are +inf-pad targets (never selected).
DEFAULT_S = (1024, 4096, 16384)
DEFAULT_N = (4, 16, 64, 128)
DEFAULT_K = (8, 32)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def effective_block_s(s, block_s):
    """Per-variant tile height.

    `block_s == 0` selects the CPU-adaptive default `min(s, 4096)`: the
    interpret-mode grid lowers to an XLA while-loop whose per-step overhead
    dominates on CPU (measured 60 ms → 13 ms on the s=16384 assign variant
    going 256 → 4096; EXPERIMENTS.md §Perf). On a real TPU target you would
    emit with the VMEM-sized 256 instead (DESIGN.md §Hardware-Adaptation).
    """
    if block_s == 0:
        return min(s, 4096)
    return block_s


def lower_variant(kind, s, n, k, tol, max_iters, block_s):
    """Lower one (kind, s, n, k) variant; returns HLO text."""
    block_s = effective_block_s(s, block_s)
    pts = jax.ShapeDtypeStruct((s, n), jnp.float32)
    cts = jax.ShapeDtypeStruct((k, n), jnp.float32)
    msk = jax.ShapeDtypeStruct((s,), jnp.float32)
    uni = jax.ShapeDtypeStruct((k,), jnp.float32)
    if kind == "lloyd":
        fn = model.make_lloyd(tol=tol, max_iters=max_iters, block_s=block_s)
        lowered = fn.lower(pts, cts, msk)
    elif kind == "assign":
        fn = model.make_assign(block_s=block_s)
        lowered = fn.lower(pts, cts, msk)
    elif kind == "kmeanspp":
        fn = model.make_kmeanspp(k, block_s=block_s)
        lowered = fn.lower(pts, msk, uni)
    else:
        raise ValueError(f"unknown kind {kind!r}")
    return to_hlo_text(lowered)


def emit(out_dir, s_list, n_list, k_list, tol, max_iters, block_s, kinds):
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    total = len(s_list) * len(n_list) * len(k_list) * len(kinds)
    done = 0
    for s in s_list:
        bs = effective_block_s(s, block_s)
        if s % bs != 0:
            raise SystemExit(f"s={s} not divisible by block_s={bs}")
        for n in n_list:
            for k in k_list:
                for kind in kinds:
                    name = f"{kind}_s{s}_n{n}_k{k}"
                    path = os.path.join(out_dir, f"{name}.hlo.txt")
                    text = lower_variant(kind, s, n, k, tol, max_iters, block_s)
                    with open(path, "w") as f:
                        f.write(text)
                    done += 1
                    print(f"[{done}/{total}] {name}: {len(text)} chars", flush=True)
                    entries.append(
                        {
                            "name": name,
                            "kind": kind,
                            "s": s,
                            "n": n,
                            "k": k,
                            "block_s": bs,
                            "tol": tol,
                            "max_iters": max_iters,
                            "file": os.path.basename(path),
                            "pad_centroid": model.PAD_CENTROID,
                        }
                    )
    manifest = {
        "version": 1,
        "jax_version": jax.__version__,
        "entries": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(entries)} artifacts + manifest.json to {out_dir}")


def parse_int_list(text, default):
    if not text:
        return list(default)
    return [int(t) for t in text.split(",") if t.strip()]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--s", default="", help="comma list of chunk sizes")
    ap.add_argument("--n", default="", help="comma list of feature dims")
    ap.add_argument("--k", default="", help="comma list of cluster counts")
    ap.add_argument("--kinds", default="lloyd,assign,kmeanspp")
    ap.add_argument("--tol", type=float, default=model.DEFAULT_TOL)
    ap.add_argument("--max-iters", type=int, default=model.DEFAULT_MAX_ITERS)
    ap.add_argument(
        "--block-s",
        type=int,
        default=0,
        help="tile height; 0 = CPU-adaptive min(s, 4096) (use 256 for TPU)",
    )
    args = ap.parse_args()
    emit(
        args.out,
        parse_int_list(args.s, DEFAULT_S),
        parse_int_list(args.n, DEFAULT_N),
        parse_int_list(args.k, DEFAULT_K),
        args.tol,
        args.max_iters,
        args.block_s,
        [k.strip() for k in args.kinds.split(",") if k.strip()],
    )


if __name__ == "__main__":
    sys.exit(main())
