"""L2: the MSSC local-search computation in JAX, calling the L1 kernel.

Big-means's inner loop ("MSSC" in Algorithm 3) is K-means/Lloyd local
search on one chunk. This module expresses it as jittable, fixed-shape JAX
functions that `aot.py` lowers once to HLO text; the rust coordinator then
executes them via PJRT with python out of the loop.

Exported computations (all shapes static per artifact variant):

* `lloyd_chunk(points, centroids, mask)` — Lloyd iterations inside a
  `lax.while_loop` with the paper's convergence rule (relative objective
  tolerance, max iteration cap). Degenerate clusters keep their previous
  centroid; the coordinator reinitialises them (K-means++) between chunks.
* `assign_chunk(points, centroids, mask)` — one assignment pass: labels +
  per-point min squared distances (used for the final full-dataset
  assignment and for K-means++ D² weights at L3).
* `kmeanspp_init(points, mask, uniforms)` — K-means++ seeding on a chunk,
  randomness supplied by the caller as `k` uniforms in [0,1) so the
  computation stays pure and AOT-able.

Padding contract (see `runtime/variant.rs`): rows beyond the real chunk
carry mask 0.0; padded feature columns are zero (distance-preserving);
padded centroid slots are parked at +PAD_CENTROID so no point selects them
and they stay degenerate.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import assign as assign_kernel

# Paper §5.7: convergence when relative objective change < 1e-4 or the
# iteration cap is hit (the paper uses n_full > 300 on the full dataset;
# chunks converge far faster, 100 is roofline in practice).
DEFAULT_TOL = 1e-4
DEFAULT_MAX_ITERS = 100

# Coordinate used to park padded/unused centroid slots out of the way.
PAD_CENTROID = 1.0e15


def _masked_count(mask):
    return jnp.maximum(jnp.sum(mask), 1.0)


def lloyd_chunk(points, centroids, mask, *, tol=DEFAULT_TOL, max_iters=DEFAULT_MAX_ITERS,
                block_s=assign_kernel.DEFAULT_BLOCK_S):
    """Lloyd local search on one chunk, seeded by `centroids`.

    Returns (centroids', objective, counts, iters):
      centroids' (k, n)  — converged centroids (padded slots untouched),
      objective  float32 — masked chunk SSE after the last assignment,
      counts     (k,)    — cluster sizes from the last assignment,
      iters      int32   — Lloyd iterations actually executed.
    """

    def step(carry):
        c, _stale, last_obj, _counts, it = carry
        _labels, mins, sums, counts = assign_kernel.assign_accumulate(
            points, c, mask, block_s=block_s
        )
        obj = jnp.sum(mins)
        safe = jnp.maximum(counts, 1.0)[:, None]
        updated = sums / safe
        new_c = jnp.where((counts == 0.0)[:, None], c, updated)
        # Shift objectives: the objective of the previous iteration becomes
        # `prev_obj`, the fresh one becomes `obj` — cond compares the two.
        return new_c, last_obj, obj, counts, it + 1

    def cond(carry):
        _c, prev_obj, obj, _counts, it = carry
        first = it < 1
        # Relative tolerance on consecutive objectives (paper §5.7).
        rel = jnp.abs(prev_obj - obj) / jnp.maximum(obj, 1e-30)
        return jnp.logical_and(it < max_iters, jnp.logical_or(first, rel > tol))

    k = centroids.shape[0]
    init = (
        centroids,
        jnp.float32(jnp.inf),
        jnp.float32(jnp.inf),
        jnp.zeros((k,), jnp.float32),
        jnp.int32(0),
    )
    # One wrinkle: `step` computes obj for the *incoming* centroids; the
    # while_loop stops when the objective stops improving. After the loop,
    # `obj` is the SSE of the second-to-last centroid set; run one more
    # masked assignment to report the SSE of the returned centroids.
    c, _prev, _obj, counts, iters = jax.lax.while_loop(cond, step, init)
    _labels, mins, _sums, counts = assign_kernel.assign_accumulate(
        points, c, mask, block_s=block_s
    )
    return c, jnp.sum(mins), counts, iters


def assign_chunk(points, centroids, mask, *, block_s=assign_kernel.DEFAULT_BLOCK_S):
    """One assignment pass: (labels, mins) for the chunk.

    labels are −1 on padded rows; mins are 0 there (so sums are exact).
    """
    labels, mins, _sums, _counts = assign_kernel.assign_accumulate(
        points, centroids, mask, block_s=block_s
    )
    return labels, mins


def objective_chunk(points, centroids, mask, *, block_s=assign_kernel.DEFAULT_BLOCK_S):
    """Masked chunk SSE for the given centroids."""
    _labels, mins = assign_chunk(points, centroids, mask, block_s=block_s)
    return jnp.sum(mins)


def kmeanspp_init(points, mask, uniforms, *, k, block_s=assign_kernel.DEFAULT_BLOCK_S):
    """K-means++ seeding on a chunk (Algorithm 2 of the paper).

    Randomness comes in as `uniforms` (k,) float32 in [0,1): draw j is the
    inverse-CDF sample of the D² distribution given uniform u_j. Masked
    rows get zero weight. Returns (k, n) centroids.

    The D² update is incremental: after adding centroid j we only compute
    distances to that one new centroid — O(s·n) per step, the same trick
    the rust-native seeding uses, so distance-eval counts match.
    """
    s, n = points.shape

    def pick(weights, u):
        # Inverse-CDF over non-negative weights; masked rows weigh 0.
        cum = jnp.cumsum(weights)
        total = cum[-1]
        target = u * total
        idx = jnp.searchsorted(cum, target, side="right")
        return jnp.clip(idx, 0, s - 1)

    # First centroid: uniform over real rows.
    first_idx = pick(mask, uniforms[0])
    first = points[first_idx]

    centroids0 = jnp.full((k, n), PAD_CENTROID, dtype=points.dtype)
    centroids0 = centroids0.at[0].set(first)

    d2_0 = jnp.sum((points - first[None, :]) ** 2, axis=1) * mask

    def body(j, carry):
        centroids, d2 = carry
        idx = pick(d2, uniforms[j])
        cj = points[idx]
        centroids = jax.lax.dynamic_update_slice(centroids, cj[None, :], (j, 0))
        d2_new = jnp.sum((points - cj[None, :]) ** 2, axis=1) * mask
        return centroids, jnp.minimum(d2, d2_new)

    centroids, _d2 = jax.lax.fori_loop(1, k, body, (centroids0, d2_0))
    return centroids


# ---------------------------------------------------------------------------
# jit wrappers with static shapes for AOT lowering (see aot.py)
# ---------------------------------------------------------------------------

def make_lloyd(tol=DEFAULT_TOL, max_iters=DEFAULT_MAX_ITERS, block_s=assign_kernel.DEFAULT_BLOCK_S):
    @jax.jit
    def fn(points, centroids, mask):
        return lloyd_chunk(points, centroids, mask, tol=tol, max_iters=max_iters,
                           block_s=block_s)
    return fn


def make_assign(block_s=assign_kernel.DEFAULT_BLOCK_S):
    @jax.jit
    def fn(points, centroids, mask):
        return assign_chunk(points, centroids, mask, block_s=block_s)
    return fn


def make_kmeanspp(k, block_s=assign_kernel.DEFAULT_BLOCK_S):
    @functools.partial(jax.jit, static_argnames=())
    def fn(points, mask, uniforms):
        return kmeanspp_init(points, mask, uniforms, k=k, block_s=block_s)
    return fn
