"""Pure-jnp reference oracle for the L1 Pallas kernels.

Every Pallas kernel in this package has a matching reference implementation
here, written in straight-line jax.numpy with no tiling, no scratch buffers,
no BlockSpecs. The pytest suite asserts allclose between kernel and oracle
across a hypothesis-style sweep of shapes and dtypes — this is the core
correctness signal for Layer 1.
"""

import jax.numpy as jnp


def pairwise_sq_dists(points, centroids):
    """Squared Euclidean distances, shape (s, k).

    points:    (s, n) float
    centroids: (k, n) float
    """
    # ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2  (same decomposition the
    # kernel uses, so numerics match to float tolerance).
    x2 = jnp.sum(points * points, axis=1, keepdims=True)  # (s, 1)
    c2 = jnp.sum(centroids * centroids, axis=1)[None, :]  # (1, k)
    xc = points @ centroids.T  # (s, k)
    return x2 - 2.0 * xc + c2


def assign(points, centroids):
    """Nearest-centroid assignment.

    Returns (labels (s,), min_dists (s,)) — min_dists are squared and
    clamped at zero (the dot-product decomposition can go slightly
    negative).
    """
    d = pairwise_sq_dists(points, centroids)
    labels = jnp.argmin(d, axis=1)
    mins = jnp.maximum(jnp.min(d, axis=1), 0.0)
    return labels, mins


def accumulate(points, labels, k):
    """Per-cluster sums and counts given labels.

    Returns (sums (k, n), counts (k,)).
    """
    onehot = jnp.eye(k, dtype=points.dtype)[labels]  # (s, k)
    sums = onehot.T @ points
    counts = jnp.sum(onehot, axis=0)
    return sums, counts


def assign_accumulate(points, centroids):
    """Fused reference of the full assignment step: labels, min-distances,
    per-cluster sums and counts. This is the contract of the Pallas kernel
    `assign.assign_accumulate`.
    """
    k = centroids.shape[0]
    labels, mins = assign(points, centroids)
    sums, counts = accumulate(points, labels, k)
    return labels, mins, sums, counts


def lloyd_step(points, centroids):
    """One Lloyd iteration: assignment + centroid update.

    Degenerate (empty) clusters keep their previous centroid — the same
    policy the L3 coordinator expects (it later reinitialises degenerates
    via K-means++ on a fresh chunk).

    Returns (new_centroids, objective, counts).
    """
    _, mins, sums, counts = assign_accumulate(points, centroids)
    safe = jnp.maximum(counts, 1.0)[:, None]
    updated = sums / safe
    keep_old = (counts == 0.0)[:, None]
    new_centroids = jnp.where(keep_old, centroids, updated)
    objective = jnp.sum(mins)
    return new_centroids, objective, counts


def lloyd(points, centroids, iters):
    """`iters` Lloyd iterations (fixed trip count — matches the AOT'd scan).

    Returns (centroids, objective_after_last_assignment, counts).
    """
    c = centroids
    obj = jnp.float32(0.0)
    counts = jnp.zeros((centroids.shape[0],), dtype=points.dtype)
    for _ in range(iters):
        c, obj, counts = lloyd_step(points, c)
    return c, obj, counts


def objective(points, centroids):
    """MSSC objective f(C, X) = sum_i min_j ||x_i - c_j||^2."""
    _, mins = assign(points, centroids)
    return jnp.sum(mins)
