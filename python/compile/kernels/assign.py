"""L1 Pallas kernel: fused assignment step of Lloyd's algorithm.

The compute hot-spot of Big-means (and of every baseline) is the
assignment step: for a chunk of `s` points and `k` centroids in
`n`-dimensional space, find each point's nearest centroid and reduce the
per-cluster sums/counts needed by the update step. This kernel fuses all
of it so a Lloyd iteration makes a single pass over the chunk.

TPU-idiomatic design (run under `interpret=True` on CPU — see DESIGN.md
§Hardware-Adaptation):

* The grid tiles the chunk into `(BLOCK_S, n)` point tiles streamed
  HBM→VMEM by the BlockSpec index_map; the `(k, n)` centroid tile is small
  (k ≤ 32, n ≤ 128 → ≤ 16 KiB fp32) and pinned whole in VMEM every step.
* Squared distances use the `‖x‖² − 2·x·Cᵀ + ‖c‖²` decomposition so the
  dominant FLOPs are a `(BLOCK_S, n) × (n, k)` contraction that maps onto
  the MXU systolic array.
* The per-cluster reduction is a second MXU contraction
  `onehotᵀ × points`, so tiles leave the kernel already reduced to
  `(k, n)` partial sums — the centroid update at L2 is a cheap division.
* Cross-tile accumulation uses the standard revisiting-output pattern:
  the sums/counts output block maps every grid step to the same window;
  step 0 initialises, later steps accumulate.

A `mask` input (1.0 = real point, 0.0 = padding) makes the kernel exact
for chunks padded up to the compiled shape: padded rows contribute nothing
to mins/sums/counts, and their labels are forced to -1.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 256×128 fp32 = 128 KiB point tile: small enough to
# double-buffer in ~16 MiB VMEM, large enough to keep the MXU busy.
DEFAULT_BLOCK_S = 256


def _assign_accumulate_kernel(x_ref, c_ref, m_ref, labels_ref, mins_ref, sums_ref, counts_ref):
    """One grid step: assignment + partial reduction for a point tile."""
    step = pl.program_id(0)
    x = x_ref[...]  # (BLOCK_S, n)
    c = c_ref[...]  # (k, n)
    mask = m_ref[...]  # (BLOCK_S,)
    k = c.shape[0]

    # Squared distances via the MXU-friendly decomposition.
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (BLOCK_S, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]  # (1, k)
    xc = jnp.dot(x, c.T, preferred_element_type=jnp.float32)  # (BLOCK_S, k)
    d = x2 - 2.0 * xc + c2

    labels = jnp.argmin(d, axis=1).astype(jnp.int32)  # (BLOCK_S,)
    mins = jnp.maximum(jnp.min(d, axis=1), 0.0)  # clamp fp slack

    valid = mask > 0.5
    labels_ref[...] = jnp.where(valid, labels, -1)
    mins_ref[...] = jnp.where(valid, mins, 0.0)

    # One-hot with a 2-D iota (TPU requires ≥2-D iota).
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], k), 1)
    onehot = (labels[:, None] == iota_k).astype(x.dtype) * mask[:, None]
    part_sums = jnp.dot(onehot.T, x, preferred_element_type=jnp.float32)  # (k, n)
    part_counts = jnp.sum(onehot, axis=0)  # (k,)

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = part_sums
        counts_ref[...] = part_counts

    @pl.when(step > 0)
    def _accumulate():
        sums_ref[...] += part_sums
        counts_ref[...] += part_counts


@functools.partial(jax.jit, static_argnames=("block_s",))
def assign_accumulate(points, centroids, mask, *, block_s=DEFAULT_BLOCK_S):
    """Fused assignment step over a whole chunk.

    Args:
      points:    (s, n) float32, s divisible by block_s (pad + mask if not).
      centroids: (k, n) float32.
      mask:      (s,) float32, 1.0 for real rows / 0.0 for padding.
      block_s:   rows per grid step.

    Returns:
      labels (s,) int32 (−1 on padded rows), mins (s,) float32,
      sums (k, n) float32, counts (k,) float32.
    """
    s, n = points.shape
    k = centroids.shape[0]
    if s % block_s != 0:
        raise ValueError(f"s={s} must be divisible by block_s={block_s}")
    grid = (s // block_s,)
    return pl.pallas_call(
        _assign_accumulate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, n), lambda i: (i, 0)),  # stream point tiles
            pl.BlockSpec((k, n), lambda i: (0, 0)),  # centroids pinned
            pl.BlockSpec((block_s,), lambda i: (i,)),  # mask tiles
        ],
        out_specs=[
            pl.BlockSpec((block_s,), lambda i: (i,)),
            pl.BlockSpec((block_s,), lambda i: (i,)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),  # revisited: accumulate
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s,), jnp.int32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,  # CPU-PJRT target; real-TPU lowering emits Mosaic
    )(points, centroids, mask)


def vmem_footprint_bytes(block_s, n, k):
    """Estimated VMEM residency of one grid step (fp32), for DESIGN §Perf.

    point tile + centroid tile + distance tile + onehot tile + outputs.
    """
    f = 4
    return (
        block_s * n * f  # x
        + k * n * f  # c
        + block_s * k * f  # d
        + block_s * k * f  # onehot
        + k * n * f  # sums
        + (2 * block_s + k) * f  # labels, mins, counts
    )


def mxu_flops_per_step(block_s, n, k):
    """MXU-routed FLOPs per grid step (two contractions), for DESIGN §Perf."""
    return 2 * block_s * n * k + 2 * block_s * k * n
