"""L2 correctness: the AOT-able model functions (Lloyd loop, K-means++)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def gaussian_blobs(seed, s, n, k_true, spread=0.05):
    """Well-separated blobs: ideal for checking Lloyd recovers structure."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-10, 10, size=(k_true, n))
    labels = rng.integers(0, k_true, size=s)
    pts = centers[labels] + rng.normal(scale=spread, size=(s, n))
    return pts.astype(np.float32), centers.astype(np.float32)


def test_lloyd_monotone_objective():
    """SSE of returned centroids ≤ SSE of the seed (Lloyd never worsens)."""
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(256, 6)).astype(np.float32)
    seed_c = rng.normal(size=(4, 6)).astype(np.float32)
    mask = np.ones((256,), np.float32)
    c, obj, _counts, iters = model.lloyd_chunk(
        jnp.asarray(pts), jnp.asarray(seed_c), jnp.asarray(mask)
    )
    start = float(ref.objective(jnp.asarray(pts), jnp.asarray(seed_c)))
    assert float(obj) <= start + 1e-3
    assert int(iters) >= 1


def test_lloyd_recovers_separated_blobs():
    pts, centers = gaussian_blobs(1, 512, 4, 4)
    mask = np.ones((512,), np.float32)
    # Seed near the true centers: Lloyd must converge to ~zero-variance SSE.
    seed_c = centers + 0.5
    c, obj, counts, iters = model.lloyd_chunk(
        jnp.asarray(pts), jnp.asarray(seed_c.astype(np.float32)), jnp.asarray(mask)
    )
    per_point = float(obj) / 512
    assert per_point < 4 * 0.05**2 * 4  # ≈ n·spread² with slack
    assert (np.asarray(counts) > 0).all()


def test_lloyd_respects_mask_padding():
    """Padded rows must not shift the solution."""
    pts, _ = gaussian_blobs(2, 200, 3, 3)
    pad = np.zeros((56, 3), np.float32)  # garbage rows beyond the mask
    full = np.vstack([pts, pad])
    mask = np.concatenate([np.ones(200), np.zeros(56)]).astype(np.float32)
    seed = pts[:4]
    c_pad, obj_pad, _cnt, _it = model.lloyd_chunk(
        jnp.asarray(full), jnp.asarray(seed), jnp.asarray(mask), block_s=64
    )
    c_ref, obj_ref, _cnt2, _it2 = model.lloyd_chunk(
        jnp.asarray(pts[:200]), jnp.asarray(seed), jnp.asarray(np.ones(200, np.float32)),
        block_s=50,
    )
    np.testing.assert_allclose(float(obj_pad), float(obj_ref), rtol=1e-3)


def test_lloyd_keeps_degenerate_centroids_in_place():
    """A far-away centroid captures nothing and must stay exactly put."""
    pts, _ = gaussian_blobs(3, 128, 2, 2)
    seed = np.vstack([pts[:2], np.full((1, 2), model.PAD_CENTROID, np.float32)])
    mask = np.ones((128,), np.float32)
    c, _obj, counts, _it = model.lloyd_chunk(
        jnp.asarray(pts), jnp.asarray(seed), jnp.asarray(mask), block_s=64
    )
    assert float(np.asarray(counts)[2]) == 0.0
    np.testing.assert_array_equal(np.asarray(c)[2], seed[2])


def test_lloyd_iteration_cap():
    rng = np.random.default_rng(4)
    pts = rng.normal(size=(256, 4)).astype(np.float32)
    seed = rng.normal(size=(8, 4)).astype(np.float32)
    mask = np.ones((256,), np.float32)
    _c, _obj, _cnt, iters = model.lloyd_chunk(
        jnp.asarray(pts), jnp.asarray(seed), jnp.asarray(mask), max_iters=3
    )
    assert int(iters) <= 3


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 6))
def test_kmeanspp_selects_real_points(seed, k):
    rng = np.random.default_rng(seed)
    s, n = 128, 4
    pts = rng.normal(size=(s, n)).astype(np.float32)
    mask = np.ones((s,), np.float32)
    u = rng.random(k).astype(np.float32)
    cs = np.asarray(model.kmeanspp_init(jnp.asarray(pts), jnp.asarray(mask), jnp.asarray(u), k=k))
    # Every selected centroid must be an actual data point.
    for j in range(k):
        d = ((pts - cs[j]) ** 2).sum(axis=1)
        assert d.min() < 1e-8, f"centroid {j} is not a data point"


def test_kmeanspp_ignores_masked_rows():
    rng = np.random.default_rng(9)
    s, n, k = 64, 3, 4
    pts = rng.normal(size=(s, n)).astype(np.float32)
    pts[32:] += 1000.0  # masked rows are far outliers — would dominate D²
    mask = np.concatenate([np.ones(32), np.zeros(32)]).astype(np.float32)
    u = rng.random(k).astype(np.float32)
    cs = np.asarray(model.kmeanspp_init(jnp.asarray(pts), jnp.asarray(mask), jnp.asarray(u), k=k))
    for j in range(k):
        d = ((pts[:32] - cs[j]) ** 2).sum(axis=1)
        assert d.min() < 1e-8, "selected a masked row"


def test_kmeanspp_spreads_over_blobs():
    """With well-separated blobs, D² seeding should hit every blob."""
    pts, centers = gaussian_blobs(5, 256, 2, 4, spread=0.01)
    mask = np.ones((256,), np.float32)
    hit_all = 0
    trials = 20
    rng = np.random.default_rng(0)
    for _ in range(trials):
        u = rng.random(4).astype(np.float32)
        cs = np.asarray(
            model.kmeanspp_init(jnp.asarray(pts), jnp.asarray(mask), jnp.asarray(u), k=4)
        )
        assigned = {int(((centers - c) ** 2).sum(axis=1).argmin()) for c in cs}
        hit_all += assigned == {0, 1, 2, 3}
    assert hit_all >= trials * 0.8  # k-means++ hits all blobs w.h.p.


def test_objective_chunk_matches_ref():
    rng = np.random.default_rng(11)
    pts = rng.normal(size=(64, 5)).astype(np.float32)
    cs = rng.normal(size=(3, 5)).astype(np.float32)
    mask = np.ones((64,), np.float32)
    got = float(model.objective_chunk(jnp.asarray(pts), jnp.asarray(cs), jnp.asarray(mask), block_s=32))
    want = float(ref.objective(jnp.asarray(pts), jnp.asarray(cs)))
    np.testing.assert_allclose(got, want, rtol=1e-4)
