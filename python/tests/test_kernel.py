"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

This is the CORE correctness signal for Layer 1 — a hypothesis sweep over
shapes, dtype-representable value ranges, masks and degenerate layouts,
asserting allclose between `kernels.assign.assign_accumulate` and
`kernels.ref.assign_accumulate`.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import assign as ak
from compile.kernels import ref


def run_both(pts, cs, mask, block_s):
    got = ak.assign_accumulate(
        jnp.asarray(pts), jnp.asarray(cs), jnp.asarray(mask), block_s=block_s
    )
    want = ref.assign_accumulate(jnp.asarray(pts), jnp.asarray(cs))
    return [np.asarray(g) for g in got], [np.asarray(w) for w in want]


def assert_matches_ref(pts, cs, mask, block_s):
    (labels, mins, sums, counts), (rl, rm, rs, rc) = run_both(pts, cs, mask, block_s)
    valid = mask > 0.5
    # Ties in argmin can break either way only when two distances are exactly
    # equal; with continuous random data this has measure zero, and both
    # kernel and ref use argmin-first semantics, so exact match is expected.
    np.testing.assert_array_equal(labels[valid], rl[valid])
    assert (labels[~valid] == -1).all()
    np.testing.assert_allclose(mins[valid], rm[valid], rtol=1e-4, atol=1e-4)
    assert (mins[~valid] == 0).all()
    if valid.all():
        np.testing.assert_allclose(sums, rs, rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(counts, rc)


@st.composite
def problems(draw):
    block_s = draw(st.sampled_from([8, 16, 32]))
    blocks = draw(st.integers(1, 6))
    s = block_s * blocks
    n = draw(st.integers(1, 24))
    k = draw(st.integers(1, 9))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.sampled_from([1e-3, 1.0, 1e3]))
    rng = np.random.default_rng(seed)
    pts = (rng.normal(size=(s, n)) * scale).astype(np.float32)
    cs = (rng.normal(size=(k, n)) * scale).astype(np.float32)
    return pts, cs, block_s


@settings(max_examples=60, deadline=None)
@given(problems())
def test_kernel_matches_ref_unmasked(problem):
    pts, cs, block_s = problem
    mask = np.ones((pts.shape[0],), np.float32)
    assert_matches_ref(pts, cs, mask, block_s)


@settings(max_examples=30, deadline=None)
@given(problems(), st.integers(0, 2**31 - 1))
def test_kernel_masked_rows_excluded(problem, mseed):
    pts, cs, block_s = problem
    s = pts.shape[0]
    rng = np.random.default_rng(mseed)
    real = rng.integers(1, s + 1)
    mask = np.zeros((s,), np.float32)
    mask[:real] = 1.0
    (labels, mins, sums, counts), (rl, rm, _rs, _rc) = run_both(pts, cs, mask, block_s)
    # Masked tail contributes nothing.
    np.testing.assert_array_equal(labels[:real], rl[:real])
    np.testing.assert_allclose(mins[:real], rm[:real], rtol=1e-4, atol=1e-4)
    want_sums, want_counts = ref.accumulate(
        jnp.asarray(pts[:real]), jnp.asarray(rl[:real]), cs.shape[0]
    )
    np.testing.assert_allclose(sums, np.asarray(want_sums), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(counts, np.asarray(want_counts))


def test_counts_sum_to_mask_total():
    rng = np.random.default_rng(7)
    pts = rng.normal(size=(64, 5)).astype(np.float32)
    cs = rng.normal(size=(3, 5)).astype(np.float32)
    mask = np.ones((64,), np.float32)
    mask[50:] = 0.0
    (_l, _m, _s, counts), _ = run_both(pts, cs, mask, 16)
    assert counts.sum() == 50.0


def test_zero_feature_padding_is_distance_preserving():
    """Zero-padding the feature dim must not change labels or mins."""
    rng = np.random.default_rng(3)
    pts = rng.normal(size=(32, 6)).astype(np.float32)
    cs = rng.normal(size=(4, 6)).astype(np.float32)
    mask = np.ones((32,), np.float32)
    (l1, m1, _s1, c1), _ = run_both(pts, cs, mask, 16)
    pts_pad = np.zeros((32, 16), np.float32)
    pts_pad[:, :6] = pts
    cs_pad = np.zeros((4, 16), np.float32)
    cs_pad[:, :6] = cs
    (l2, m2, _s2, c2), _ = run_both(pts_pad, cs_pad, mask, 16)
    np.testing.assert_array_equal(l1, l2)
    np.testing.assert_allclose(m1, m2, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(c1, c2)


def test_far_centroid_padding_never_selected():
    """Centroid slots parked at +PAD are never selected and stay empty."""
    from compile import model

    rng = np.random.default_rng(4)
    pts = rng.normal(size=(32, 4)).astype(np.float32)
    cs = np.full((8, 4), model.PAD_CENTROID, np.float32)
    cs[:3] = rng.normal(size=(3, 4)).astype(np.float32)
    mask = np.ones((32,), np.float32)
    (labels, _m, _s, counts), _ = run_both(pts, cs, mask, 16)
    assert labels.max() < 3
    assert (counts[3:] == 0).all()


def test_single_cluster_degenerate_k1():
    rng = np.random.default_rng(5)
    pts = rng.normal(size=(16, 3)).astype(np.float32)
    cs = rng.normal(size=(1, 3)).astype(np.float32)
    mask = np.ones((16,), np.float32)
    (labels, mins, sums, counts), _ = run_both(pts, cs, mask, 8)
    assert (labels == 0).all()
    assert counts[0] == 16
    np.testing.assert_allclose(sums[0], pts.sum(axis=0), rtol=1e-4)
    np.testing.assert_allclose(
        mins, ((pts - cs[0]) ** 2).sum(axis=1), rtol=1e-4, atol=1e-4
    )


def test_identical_points_tie_break_low_index():
    """Point equidistant to two identical centroids → argmin picks index 0."""
    pts = np.ones((8, 2), np.float32)
    cs = np.ones((2, 2), np.float32)
    mask = np.ones((8,), np.float32)
    (labels, mins, _s, counts), _ = run_both(pts, cs, mask, 8)
    assert (labels == 0).all()
    assert counts[0] == 8 and counts[1] == 0
    np.testing.assert_allclose(mins, 0.0, atol=1e-6)


def test_block_s_invariance():
    """Result must not depend on the tiling block size."""
    rng = np.random.default_rng(6)
    pts = rng.normal(size=(96, 7)).astype(np.float32)
    cs = rng.normal(size=(5, 7)).astype(np.float32)
    mask = np.ones((96,), np.float32)
    outs = []
    for bs in (8, 16, 32, 96):
        (labels, mins, sums, counts), _ = run_both(pts, cs, mask, bs)
        outs.append((labels, mins, sums, counts))
    for other in outs[1:]:
        np.testing.assert_array_equal(outs[0][0], other[0])
        np.testing.assert_allclose(outs[0][1], other[1], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(outs[0][2], other[2], rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(outs[0][3], other[3])


def test_indivisible_block_raises():
    pts = np.zeros((10, 2), np.float32)
    cs = np.zeros((2, 2), np.float32)
    mask = np.ones((10,), np.float32)
    with pytest.raises(ValueError, match="divisible"):
        ak.assign_accumulate(
            jnp.asarray(pts), jnp.asarray(cs), jnp.asarray(mask), block_s=4
        )


def test_vmem_and_flops_estimates_positive():
    assert ak.vmem_footprint_bytes(256, 128, 32) < 4 << 20  # fits VMEM budget
    assert ak.mxu_flops_per_step(256, 128, 32) > 0
