"""AOT pipeline tests: lowering produces loadable, correct HLO text.

These execute the *lowered* computation through jax's own runtime (the
rust integration test `runtime_roundtrip.rs` covers the PJRT-from-rust
half) and check the manifest contract the rust runtime relies on.
"""

import json
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model


def test_hlo_text_parses_as_hlo_module():
    text = aot.lower_variant("assign", 64, 4, 2, model.DEFAULT_TOL, 10, 32)
    assert "HloModule" in text
    assert "ENTRY" in text
    # while-free assign: no control flow expected
    assert text.count("ROOT") >= 1


def test_lloyd_hlo_contains_while_loop():
    text = aot.lower_variant("lloyd", 64, 4, 2, model.DEFAULT_TOL, 10, 32)
    assert "while" in text, "convergence loop should lower to an HLO while"


def test_manifest_contract():
    with tempfile.TemporaryDirectory() as d:
        aot.emit(d, [64], [4], [2], 1e-4, 10, 32, ["lloyd", "assign", "kmeanspp"])
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        assert manifest["version"] == 1
        entries = manifest["entries"]
        assert len(entries) == 3
        kinds = {e["kind"] for e in entries}
        assert kinds == {"lloyd", "assign", "kmeanspp"}
        for e in entries:
            assert os.path.exists(os.path.join(d, e["file"]))
            assert e["s"] == 64 and e["n"] == 4 and e["k"] == 2
            assert e["block_s"] == 32
            assert e["pad_centroid"] == model.PAD_CENTROID


def test_lowered_assign_executes_correctly():
    """Compile the lowered StableHLO and compare against direct execution."""
    s, n, k, bs = 64, 4, 3, 32
    fn = model.make_assign(block_s=bs)
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(s, n)).astype(np.float32)
    cs = rng.normal(size=(k, n)).astype(np.float32)
    mask = np.ones((s,), np.float32)
    lowered = fn.lower(
        jax.ShapeDtypeStruct((s, n), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
        jax.ShapeDtypeStruct((s,), jnp.float32),
    )
    compiled = lowered.compile()
    got_l, got_m = compiled(jnp.asarray(pts), jnp.asarray(cs), jnp.asarray(mask))
    want_l, want_m = fn(jnp.asarray(pts), jnp.asarray(cs), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(want_l))
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m), rtol=1e-6)


def test_indivisible_s_rejected():
    import pytest

    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(SystemExit, match="divisible"):
            aot.emit(d, [100], [4], [2], 1e-4, 10, 32, ["assign"])


def test_parse_int_list():
    assert aot.parse_int_list("", (1, 2)) == [1, 2]
    assert aot.parse_int_list("4,8, 16", ()) == [4, 8, 16]
